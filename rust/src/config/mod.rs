//! Campaign configuration: defaults that encode the paper's exercise,
//! overridable from a TOML file and CLI flags.
//!
//! Layout:
//! - [`registry`] — the typed knob registry: one declarative table
//!   driving scenario parsing, campaign TOML parsing, grid-axis
//!   whitelisting, `icecloud knobs` and the pinned doc tables.
//! - [`scenario`] — the campaign/scenario types ([`CampaignConfig`],
//!   ramp/outage/checkpoint/NAT specs), the shared value validators
//!   and the canonical (cache-key) serialization.
//! - [`engine`] / [`server`] / [`fleet`] / [`ops`] — wall-time and
//!   serving knobs that deliberately never reach the cache key.

pub mod engine;
pub mod fleet;
pub mod ops;
pub mod registry;
pub mod scenario;
pub mod server;

pub use engine::{EngineConfig, RealComputeConfig};
pub use fleet::FleetConfig;
pub use ops::OpsConfig;
pub use scenario::{
    load_toml_doc, spec_seconds, spec_u32, CampaignConfig, CheckpointPolicy,
    NatOverride, OutageSpec, PolicyMode, ProviderWeights, RampStep,
    DEFAULT_RESUME_OVERHEAD_S,
};
pub use server::ServerConfig;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SimdMode;
    use crate::sim::{DAY, HOUR};
    use crate::util::json::Json;
    use crate::util::toml;

    #[test]
    fn defaults_encode_the_paper() {
        let c = CampaignConfig::default();
        assert_eq!(c.duration_s, 14 * DAY);
        assert_eq!(c.budget_usd, 58_000.0);
        let targets: Vec<u32> = c.ramp.iter().map(|s| s.target).collect();
        assert_eq!(targets, vec![50, 400, 900, 1200, 1600, 2000]);
        assert!(c.outage.is_some());
        match c.policy {
            PolicyMode::Fixed(w) => assert!(w.azure > w.aws && w.azure > w.gcp),
            _ => panic!("default policy is fixed Azure-favoring"),
        }
    }

    #[test]
    fn toml_overrides() {
        let doc = toml::parse(
            r#"
seed = 7
duration_days = 2.0
keepalive_s = 300

[budget]
total_usd = 1000.0
alerts = [0.5]

[ramp]
targets = [10, 20]
hold_days = [0.5, 1.0]

[outage]
at_days = 1.0
duration_hours = 3.0

[policy]
aws = 0.2
gcp = 0.2
azure = 0.6
"#,
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.duration_s, 2 * DAY);
        assert_eq!(c.keepalive_s, 300);
        assert_eq!(c.budget_usd, 1000.0);
        assert_eq!(c.alert_thresholds, vec![0.5]);
        assert_eq!(c.ramp.len(), 2);
        assert_eq!(c.ramp[0], RampStep { target: 10, hold_s: DAY / 2 });
        assert_eq!(
            c.outage,
            Some(OutageSpec { at_s: DAY, duration_s: 3 * HOUR })
        );
        match c.policy {
            PolicyMode::Fixed(w) => assert_eq!(w.azure, 0.6),
            _ => panic!(),
        }
    }

    #[test]
    fn outage_can_be_disabled() {
        let doc = toml::parse("[outage]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert!(c.outage.is_none());
    }

    #[test]
    fn scenario_knobs_from_toml() {
        let doc = toml::parse(
            "preempt_multiplier = 4.0\n[nat]\nidle_timeout_s = 120",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.preempt_multiplier, 4.0);
        assert_eq!(c.nat_override, NatOverride::IdleTimeout(120));

        let doc = toml::parse("[nat]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.nat_override, NatOverride::Disabled);
    }

    #[test]
    fn conflicting_nat_knobs_rejected() {
        let doc =
            toml::parse("[nat]\ndisabled = true\nidle_timeout_s = 120")
                .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn scenario_knob_defaults_are_neutral() {
        let c = CampaignConfig::default();
        assert_eq!(c.preempt_multiplier, 1.0);
        assert_eq!(c.nat_override, NatOverride::ProviderDefault);
    }

    #[test]
    fn adaptive_policy_selectable() {
        let doc = toml::parse("[policy]\nmode = \"adaptive\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.policy, PolicyMode::Adaptive);
    }

    #[test]
    fn bad_policy_mode_rejected() {
        let doc = toml::parse("[policy]\nmode = \"nope\"").unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn fixed_mode_without_weights_cannot_mask_adaptive() {
        // mode = "fixed" on an already-fixed policy keeps its weights
        let doc = toml::parse("[policy]\nmode = \"fixed\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert!(matches!(c.policy, PolicyMode::Fixed(_)));
        // ...but on an adaptive policy it must error, not silently
        // replay adaptive under a "fixed" spec
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::Adaptive;
        assert!(c.apply_toml(&doc).is_err());
        // mode = "fixed" + weights pins those weights
        let doc = toml::parse(
            "[policy]\nmode = \"fixed\"\naws = 0.1\ngcp = 0.1\nazure = 0.8",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::Adaptive;
        c.apply_toml(&doc).unwrap();
        match c.policy {
            PolicyMode::Fixed(w) => assert_eq!(w.azure, 0.8),
            _ => panic!("expected fixed policy"),
        }
    }

    #[test]
    fn adaptive_mode_with_weights_is_a_conflict() {
        let doc = toml::parse(
            "[policy]\nmode = \"adaptive\"\naws = 0.5\ngcp = 0.3\nazure = 0.2",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn mistyped_values_rejected_not_silently_ignored() {
        for src in [
            "seed = \"7\"",
            "duration_days = true",
            "keepalive_s = 1.5",
            "[budget]\ntotal_usd = \"1000\"",
            "[budget]\nalerts = [0.5, \"0.25\"]",
            "[nat]\ndisabled = \"yes\"",
            "[outage]\nat_days = \"1\"",
            "[policy]\nmode = 3",
            "[policy]\naws = 0.5",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(
                c.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn lenient_ramp_parsing_is_gone() {
        // a dropped entry used to shift the target/hold pairing and an
        // all-mistyped list used to leave an empty (dead) ramp
        for src in [
            "[ramp]\ntargets = [100.5, 500]",
            "[ramp]\ntargets = []",
            "[ramp]\ntargets = [\"100\"]",
            "[ramp]\ntargets = [100]\nhold_days = [1.0, 2.0]",
            "[ramp]\ntargets = [100, 200]\nhold_days = [1.0, \"2\"]",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
        // fewer holds than targets still defaults the tail to 2 days
        let doc = toml::parse(
            "[ramp]\ntargets = [100, 200]\nhold_days = [1.0]",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.ramp[0].hold_s, DAY);
        assert_eq!(c.ramp[1].hold_s, 2 * DAY);
    }

    #[test]
    fn corrupting_casts_rejected_not_saturated() {
        // `f64 as u64` saturates negatives/NaN to 0 and +inf to
        // u64::MAX; `u64 as u32` truncates modulo 2^32.  Every one of
        // these used to parse Ok with a silently corrupted value.
        for src in [
            "duration_days = -1.0",
            "[outage]\nat_days = -3.0",
            "[outage]\nat_days = 1.0\nduration_hours = -2.0",
            "[outage]\nduration_hours = 2.0",
            "[ramp]\ntargets = [100]\nhold_days = [-1.0]",
            "[ramp]\ntargets = [4294967297]",
            "[onprem]\nslots = 4294967297",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
        // non-finite values have no TOML/JSON spelling, but the Json
        // tree can carry them (and the cast saturates them too)
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut doc = Json::obj();
            doc.set("duration_days", Json::from(v));
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "{v} must error");
        }
    }

    #[test]
    fn spec_helpers_guard_ranges() {
        assert_eq!(spec_seconds(2.0, DAY, "x").unwrap(), 2 * DAY);
        assert_eq!(spec_seconds(0.5, DAY, "x").unwrap(), DAY / 2);
        assert_eq!(spec_seconds(0.0, HOUR, "x").unwrap(), 0);
        assert!(spec_seconds(-0.5, DAY, "x").is_err());
        assert!(spec_seconds(f64::NAN, DAY, "x").is_err());
        assert!(spec_seconds(f64::INFINITY, HOUR, "x").is_err());
        // a duration that overflows u64 seconds is out of range, not
        // saturated
        assert!(spec_seconds(3.0e18, DAY, "x").is_err());
        assert_eq!(spec_u32(10, "x").unwrap(), 10);
        assert_eq!(spec_u32(u32::MAX as u64, "x").unwrap(), u32::MAX);
        let err = spec_u32(u32::MAX as u64 + 2, "x").unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn engine_knobs_from_toml() {
        let doc = toml::parse(
            "[engine]\nthreads = 4\nbunch = 1024\nsimd = \"off\"",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.engine.threads, 4);
        assert_eq!(c.engine.bunch, 1024);
        assert_eq!(c.engine.simd, SimdMode::Off);
        assert_eq!(c.engine.resolved_threads(), 4);
        assert_eq!(c.engine.plan().threads, 4);
        assert_eq!(c.engine.plan().bunch, 1024);
        assert_eq!(c.engine.plan().simd, SimdMode::Off);

        // the default is the lane sweep; "lanes" spells it explicitly
        let doc = toml::parse("[engine]\nsimd = \"lanes\"").unwrap();
        let mut c = CampaignConfig::default();
        c.engine.simd = SimdMode::Off;
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.engine.simd, SimdMode::Lanes);

        // mistyped, degenerate, or u32-truncating values are rejected,
        // not dropped (4294967296 = 2^32 would truncate to 0)
        for src in [
            "[engine]\nthreads = \"4\"",
            "[engine]\nbunch = 0",
            "[engine]\nbunch = 4294967296",
            "[engine]\nthreads = 4294967296",
            "[engine]\nsimd = \"avx\"",
            "[engine]\nsimd = 4",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
    }

    #[test]
    fn engine_default_is_auto() {
        let c = CampaignConfig::default();
        assert_eq!(c.engine.threads, 0);
        assert!(c.engine.resolved_threads() >= 1);
    }

    #[test]
    fn engine_clamp_respects_budget() {
        let mut e = EngineConfig { threads: 16, ..EngineConfig::default() };
        e.clamp_threads(4);
        assert_eq!(e.threads, 4);
        let mut e = EngineConfig { threads: 2, ..EngineConfig::default() };
        e.clamp_threads(4);
        assert_eq!(e.threads, 2);
        // auto resolves to a concrete count within budget
        let mut e = EngineConfig::default();
        e.clamp_threads(1);
        assert_eq!(e.threads, 1);
        // a zero budget still leaves one engine thread
        let mut e = EngineConfig { threads: 8, ..EngineConfig::default() };
        e.clamp_threads(0);
        assert_eq!(e.threads, 1);
    }

    #[test]
    fn engine_knobs_never_split_the_cache_key() {
        // the batched engine is bit-identical across these knobs, so
        // they are excluded from the canonical serialization
        let base = CampaignConfig::default().canonical_json().to_string_compact();
        let mut c = CampaignConfig::default();
        c.engine.threads = 7;
        c.engine.bunch = 128;
        c.engine.simd = SimdMode::Off;
        assert_eq!(base, c.canonical_json().to_string_compact());
    }

    #[test]
    fn canonical_json_is_stable_and_complete() {
        let a = CampaignConfig::default().canonical_json().to_string_compact();
        let b = CampaignConfig::default().canonical_json().to_string_compact();
        assert_eq!(a, b, "identical configs must serialize identically");
        // every replay-relevant scalar knob must appear by name
        for key in [
            "seed", "duration_s", "tick_s", "budget_usd", "keepalive_s",
            "preempt_multiplier", "nat_override", "checkpoint", "ramp",
            "outage", "policy", "onprem", "generator", "flops_per_bunch",
        ] {
            assert!(a.contains(&format!("\"{key}\"")), "missing {key}: {a}");
        }
    }

    #[test]
    fn canonical_json_distinguishes_configs() {
        let base = CampaignConfig::default().canonical_json().to_string_compact();
        let mut c = CampaignConfig::default();
        c.seed += 1;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.nat_override = NatOverride::IdleTimeout(240);
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.outage = None;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::Adaptive;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::RiskAware;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 120,
        };
        assert_ne!(base, c.canonical_json().to_string_compact());
        // the two interval knobs split keys independently
        let mut d = CampaignConfig::default();
        d.checkpoint = CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 60,
        };
        assert_ne!(
            c.canonical_json().to_string_compact(),
            d.canonical_json().to_string_compact()
        );
    }

    #[test]
    fn checkpoint_knobs_from_toml() {
        let doc = toml::parse(
            "[checkpoint]\nevery_s = 1800\nresume_overhead_s = 60",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.checkpoint,
            CheckpointPolicy::Interval { every_s: 1800, resume_overhead_s: 60 }
        );

        // overhead defaults when only the interval is given
        let doc = toml::parse("[checkpoint]\nevery_s = 600").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.checkpoint,
            CheckpointPolicy::Interval {
                every_s: 600,
                resume_overhead_s: DEFAULT_RESUME_OVERHEAD_S,
            }
        );

        // disabled = true forces the paper baseline over a set policy
        let doc = toml::parse("[checkpoint]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 600,
            resume_overhead_s: 60,
        };
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.checkpoint, CheckpointPolicy::None);

        // mistyped / degenerate / conflicting spellings are errors
        for src in [
            "[checkpoint]\nevery_s = 0",
            "[checkpoint]\nevery_s = \"1800\"",
            "[checkpoint]\nevery_s = 30.5",
            "[checkpoint]\nresume_overhead_s = 60",
            "[checkpoint]\ndisabled = true\nevery_s = 600",
            "[checkpoint]\ndisabled = \"yes\"",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
    }

    #[test]
    fn checkpoint_default_is_paper_baseline() {
        let c = CampaignConfig::default();
        assert_eq!(c.checkpoint, CheckpointPolicy::None);
        assert_eq!(c.checkpoint.resume_overhead_s(), 0);
        assert_eq!(c.checkpoint.salvageable(10_000), 0);
    }

    #[test]
    fn checkpoint_salvage_floors_to_interval() {
        let p = CheckpointPolicy::Interval {
            every_s: 600,
            resume_overhead_s: 120,
        };
        assert_eq!(p.salvageable(0), 0);
        assert_eq!(p.salvageable(599), 0);
        assert_eq!(p.salvageable(600), 600);
        assert_eq!(p.salvageable(1799), 1200);
        assert_eq!(p.resume_overhead_s(), 120);
    }

    #[test]
    fn risk_aware_policy_selectable_and_conflicts_with_weights() {
        let doc = toml::parse("[policy]\nmode = \"risk-aware\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.policy, PolicyMode::RiskAware);

        let doc = toml::parse(
            "[policy]\nmode = \"risk-aware\"\naws = 0.5\ngcp = 0.3\nazure = 0.2",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());

        // mode = "fixed" on a risk-aware policy without weights errors
        let doc = toml::parse("[policy]\nmode = \"fixed\"").unwrap();
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::RiskAware;
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn canonical_json_round_trips_through_parser() {
        let j = CampaignConfig::default().canonical_json();
        let parsed =
            crate::util::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn server_knobs_from_toml() {
        let doc = toml::parse(
            "[server]\nqueue_max = 8\njob_runners = 3\ncache_mb = 16\n\
             store_dir = \"/var/lib/icecloud\"\njobs_keep = 16",
        )
        .unwrap();
        let mut s = ServerConfig::default();
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.queue_max, 8);
        assert_eq!(s.job_runners, 3);
        assert_eq!(s.cache_mb, 16);
        assert_eq!(s.store_dir.as_deref(), Some("/var/lib/icecloud"));
        assert_eq!(s.jobs_keep, 16);

        // the empty string is the explicit memory-only spelling
        let doc = toml::parse("[server]\nstore_dir = \"\"").unwrap();
        let mut s = ServerConfig::default();
        s.store_dir = Some("something".into());
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.store_dir, None);
    }

    #[test]
    fn server_defaults_are_sane() {
        let s = ServerConfig::default();
        assert!(s.queue_max >= 1);
        assert!(s.job_runners >= 1);
        assert!(s.cache_mb >= 1);
        assert_eq!(s.store_dir.as_deref(), Some("icecloud-store"));
        assert_eq!(s.jobs_keep, 1024);
        // a doc without a [server] table changes nothing
        let doc = toml::parse("seed = 7").unwrap();
        let mut t = ServerConfig::default();
        t.apply_toml(&doc).unwrap();
        assert_eq!(t, s);
    }

    #[test]
    fn mistyped_server_knobs_rejected_not_silently_ignored() {
        for src in [
            "[server]\nqueue_max = \"8\"",
            "[server]\nqueue_max = 0",
            "[server]\nqueue_max = 4294967296",
            "[server]\njob_runners = 0",
            "[server]\njob_runners = 1.5",
            "[server]\ncache_mb = 0",
            "[server]\ncache_mb = \"64\"",
            "[server]\nstore_dir = 7",
            "[server]\njobs_keep = 0",
            "[server]\njobs_keep = \"1024\"",
            "[server]\njobs_keep = 4294967296",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut s = ServerConfig::default();
            assert!(
                s.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn server_knobs_never_touch_the_campaign_cache_key() {
        // the [server] table rides in the same TOML file as the
        // campaign; applying it to CampaignConfig must be a no-op for
        // the canonical serialization (serving knobs cannot split the
        // result cache)
        let doc = toml::parse(
            "[server]\nqueue_max = 2\nstore_dir = \"x\"",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.canonical_json().to_string_compact(),
            CampaignConfig::default()
                .canonical_json()
                .to_string_compact()
        );
    }

    /// Round-trip helper: `from_canonical_json` must reconstruct a
    /// config whose canonical form is byte-identical (no `PartialEq`
    /// on `CampaignConfig`; the canonical string IS its identity).
    fn assert_canonical_round_trip(c: &CampaignConfig) {
        let j = c.canonical_json();
        let back = CampaignConfig::from_canonical_json(&j).unwrap();
        assert_eq!(
            back.canonical_json().to_string_compact(),
            j.to_string_compact()
        );
    }

    #[test]
    fn canonical_json_inverts_for_every_variant() {
        assert_canonical_round_trip(&CampaignConfig::default());

        let mut c = CampaignConfig::default();
        c.nat_override = NatOverride::IdleTimeout(240);
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 60,
        };
        c.outage = None;
        c.policy = PolicyMode::Adaptive;
        c.alert_thresholds = vec![0.9];
        assert_canonical_round_trip(&c);

        let mut c = CampaignConfig::default();
        c.nat_override = NatOverride::Disabled;
        c.policy = PolicyMode::RiskAware;
        c.real_compute = Some(RealComputeConfig {
            variant: "small".into(),
            every_n_completions: 100,
        });
        c.generator.request_memory_mb = 4096;
        c.ramp = vec![RampStep { target: 10, hold_s: DAY }];
        assert_canonical_round_trip(&c);
    }

    #[test]
    fn canonical_json_round_trip_survives_the_wire() {
        // the fleet sends the canonical form through the JSON parser
        let c = CampaignConfig::default();
        let wire = c.canonical_json().to_string_compact();
        let parsed = crate::util::json::parse(&wire).unwrap();
        let back = CampaignConfig::from_canonical_json(&parsed).unwrap();
        assert_eq!(back.canonical_json().to_string_compact(), wire);
    }

    #[test]
    fn from_canonical_json_is_strict() {
        let good = CampaignConfig::default().canonical_json();

        // wrong version
        let mut wrong_v = good.clone();
        wrong_v.set("v", Json::from(1u64));
        assert!(CampaignConfig::from_canonical_json(&wrong_v).is_err());

        // missing field
        let mut missing = good.clone();
        if let Json::Obj(m) = &mut missing {
            m.remove("keepalive_s");
        }
        assert!(CampaignConfig::from_canonical_json(&missing).is_err());

        // mistyped field
        let mut mistyped = good.clone();
        mistyped.set("budget_usd", Json::from("58000"));
        assert!(CampaignConfig::from_canonical_json(&mistyped).is_err());

        // malformed enum encodings
        for (key, bad) in [
            ("nat_override", Json::from("nope")),
            ("checkpoint", Json::from(3u64)),
            ("policy", Json::from("fixed")),
        ] {
            let mut doc = good.clone();
            doc.set(key, bad);
            assert!(
                CampaignConfig::from_canonical_json(&doc).is_err(),
                "malformed '{key}' must be rejected"
            );
        }
    }

    #[test]
    fn fleet_knobs_from_toml() {
        let doc = toml::parse(
            "[fleet]\nlease_ttl_s = 60\nheartbeat_every_s = 15\n\
             spot_check_rate = 0.5",
        )
        .unwrap();
        let mut f = FleetConfig::default();
        f.apply_toml(&doc).unwrap();
        assert_eq!(f.lease_ttl_s, 60);
        assert_eq!(f.heartbeat_every_s, 15);
        assert_eq!(f.spot_check_rate, 0.5);

        // a doc without a [fleet] table changes nothing
        let doc = toml::parse("seed = 7").unwrap();
        let mut f = FleetConfig::default();
        f.apply_toml(&doc).unwrap();
        assert_eq!(f, FleetConfig::default());
    }

    #[test]
    fn mistyped_fleet_knobs_rejected_not_silently_ignored() {
        for src in [
            "[fleet]\nlease_ttl_s = \"30\"",
            "[fleet]\nlease_ttl_s = 0",
            "[fleet]\nlease_ttl_s = 1.5",
            "[fleet]\nheartbeat_every_s = 0",
            "[fleet]\nheartbeat_every_s = true",
            "[fleet]\nspot_check_rate = \"0.1\"",
            "[fleet]\nspot_check_rate = -0.5",
            "[fleet]\nspot_check_rate = 1.5",
            // a heartbeat slower than the TTL would expire every lease
            "[fleet]\nlease_ttl_s = 10\nheartbeat_every_s = 10",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut f = FleetConfig::default();
            assert!(
                f.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn ops_knobs_from_toml() {
        let doc = toml::parse(
            "[ops]\nevents_ring = 64\nsample_every_s = 2",
        )
        .unwrap();
        let mut o = OpsConfig::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!(o.events_ring, 64);
        assert_eq!(o.sample_every_s, 2);

        // a doc without an [ops] table changes nothing
        let doc = toml::parse("seed = 7").unwrap();
        let mut o = OpsConfig::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!(o, OpsConfig::default());
    }

    #[test]
    fn ops_defaults_are_sane() {
        let o = OpsConfig::default();
        assert!(o.events_ring >= 1);
        assert!(o.sample_every_s >= 1);
    }

    #[test]
    fn mistyped_ops_knobs_rejected_not_silently_ignored() {
        for src in [
            "[ops]\nevents_ring = 0",
            "[ops]\nevents_ring = \"1024\"",
            "[ops]\nevents_ring = 1.5",
            "[ops]\nevents_ring = 4294967296",
            "[ops]\nsample_every_s = 0",
            "[ops]\nsample_every_s = true",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut o = OpsConfig::default();
            assert!(
                o.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn ops_knobs_never_touch_the_campaign_cache_key() {
        // the [ops] table rides in the same TOML file as the campaign;
        // applying it to CampaignConfig must be a no-op for the
        // canonical serialization (observation knobs cannot split the
        // result cache)
        let doc = toml::parse(
            "[ops]\nevents_ring = 2\nsample_every_s = 1",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.canonical_json().to_string_compact(),
            CampaignConfig::default()
                .canonical_json()
                .to_string_compact()
        );
    }

    #[test]
    fn fleet_knobs_never_touch_the_campaign_cache_key() {
        let doc = toml::parse(
            "[fleet]\nlease_ttl_s = 5\nheartbeat_every_s = 1",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.canonical_json().to_string_compact(),
            CampaignConfig::default()
                .canonical_json()
                .to_string_compact()
        );
    }

    #[test]
    fn new_knobs_apply_from_campaign_toml() {
        let doc = toml::parse(
            "gpu_slots_per_instance = 4\n\n\
             [checkpoint]\nevery_s = 900\nsize_gb = 8.0\n\
             transfer_mbps = 50.0",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.gpu_slots_per_instance, 4);
        assert_eq!(c.checkpoint_size_gb, 8.0);
        assert_eq!(c.checkpoint_transfer_mbps, 50.0);
        // 8 GB at 50 Mbps = 8 * 8000 / 50 = 1280 s on the wire
        assert_eq!(c.checkpoint_transfer_s(), 1280);
        match c.effective_checkpoint() {
            CheckpointPolicy::Interval {
                every_s,
                resume_overhead_s,
            } => {
                assert_eq!(every_s, 900);
                assert_eq!(
                    resume_overhead_s,
                    DEFAULT_RESUME_OVERHEAD_S + 1280
                );
            }
            other => panic!("expected interval policy, got {other:?}"),
        }
    }

    #[test]
    fn checkpoint_transfer_needs_a_checkpoint_policy() {
        // transfer cost only materializes when checkpointing is on:
        // with the restart-from-scratch baseline there is no restore
        // to pay for
        let mut c = CampaignConfig::default();
        c.checkpoint_size_gb = 8.0;
        c.checkpoint_transfer_mbps = 50.0;
        assert_eq!(c.effective_checkpoint(), CheckpointPolicy::None);
        // and a zero-size image is free to move
        let mut c = CampaignConfig::default();
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 900,
            resume_overhead_s: 30,
        };
        assert_eq!(
            c.effective_checkpoint(),
            CheckpointPolicy::Interval {
                every_s: 900,
                resume_overhead_s: 30
            }
        );
    }

    #[test]
    fn new_knob_values_are_validated() {
        for bad in [
            "gpu_slots_per_instance = 0",
            "[checkpoint]\nsize_gb = -1.0",
            "[checkpoint]\ntransfer_mbps = 0.0",
            "[checkpoint]\ntransfer_mbps = -2.0",
        ] {
            let doc = toml::parse(bad).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn new_knobs_at_default_are_omitted_from_canonical_json() {
        // registering a knob must never invalidate pre-existing cache
        // keys: at their defaults the PR 10 knobs are absent from the
        // canonical form entirely
        let base =
            CampaignConfig::default().canonical_json().to_string_compact();
        for key in [
            "gpu_slots_per_instance",
            "checkpoint_size_gb",
            "checkpoint_transfer_mbps",
        ] {
            assert!(
                !base.contains(key),
                "default canonical form must omit {key}: {base}"
            );
        }
        // off-default values split the key and round-trip
        let mut c = CampaignConfig::default();
        c.gpu_slots_per_instance = 4;
        c.checkpoint_size_gb = 2.5;
        c.checkpoint_transfer_mbps = 500.0;
        let canon = c.canonical_json();
        let s = canon.to_string_compact();
        assert_ne!(base, s);
        let back = CampaignConfig::from_canonical_json(&canon).unwrap();
        assert_eq!(back.gpu_slots_per_instance, 4);
        assert_eq!(back.checkpoint_size_gb, 2.5);
        assert_eq!(back.checkpoint_transfer_mbps, 500.0);
        assert_eq!(s, back.canonical_json().to_string_compact());
    }
}
