//! The scenario/campaign configuration surface: every knob a spec
//! file, a `[scenario.<name>]` table or a `[grid]` axis can touch,
//! plus its canonical (cache-key) serialization.
//!
//! Parsing is registry-driven: the per-knob table lives in
//! [`super::registry`], and [`CampaignConfig::apply_toml`] delegates
//! to it.  This module owns the *types* (RampStep, OutageSpec,
//! CheckpointPolicy, NatOverride, CampaignConfig), the shared value
//! validators ([`spec_seconds`], [`spec_u32`]) and the canonical JSON
//! round-trip whose bytes are pinned by `tests/golden_canonical.rs`.

use super::engine::{EngineConfig, RealComputeConfig};
use crate::sim::{SimTime, DAY, HOUR, MINUTE};
use crate::util::json::{require_f64, require_u64, Json};
use crate::util::toml;
use crate::workload::{GeneratorConfig, OnPremConfig};
/// One step of the operators' ramp plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampStep {
    /// Desired total cloud GPUs during this step.
    pub target: u32,
    /// How long to hold before advancing.
    pub hold_s: SimTime,
}

impl RampStep {
    /// Stable serialization for cache keying (see
    /// [`CampaignConfig::canonical_json`]).
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("target", Json::from(self.target as u64));
        o.set("hold_s", Json::from(self.hold_s));
        o
    }
}

/// A scheduled network outage of the provider hosting the CE.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutageSpec {
    pub at_s: SimTime,
    pub duration_s: SimTime,
}

impl OutageSpec {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("at_s", Json::from(self.at_s));
        o.set("duration_s", Json::from(self.duration_s));
        o
    }
}

/// Provider preference weights (aws, gcp, azure order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProviderWeights {
    pub aws: f64,
    pub gcp: f64,
    pub azure: f64,
}

/// Target distribution policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyMode {
    /// Fixed provider weights (the paper's Azure-favoring choice).
    Fixed(ProviderWeights),
    /// Adapt weights to observed price and preemption rates.
    Adaptive,
    /// Region-level risk pricing: each region's share of the ramp
    /// target is proportional to its market depth discounted by price
    /// and its *observed* reclaim+churn rate.  The paper's
    /// Azure-favoring becomes an emergent outcome instead of a
    /// hardcoded weight vector — see `coordinator::policy`.
    RiskAware,
}

impl PolicyMode {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        match self {
            PolicyMode::Adaptive => Json::from("adaptive"),
            PolicyMode::RiskAware => Json::from("risk-aware"),
            PolicyMode::Fixed(w) => {
                let mut f = Json::obj();
                f.set("aws", Json::from(w.aws));
                f.set("gcp", Json::from(w.gcp));
                f.set("azure", Json::from(w.azure));
                let mut o = Json::obj();
                o.set("fixed", f);
                o
            }
        }
    }
}

/// Default checkpoint-restore cost: re-staging input state and
/// re-priming the GPU before fresh bunches propagate.
pub const DEFAULT_RESUME_OVERHEAD_S: u64 = 120;

/// Checkpoint/restart policy for IceCube jobs (DESIGN.md §15).
///
/// The paper's jobs restarted from scratch on every interruption —
/// every preempted wall-hour was wasted.  `Interval` models periodic
/// checkpoints at photon-bunch granularity: a preempted or
/// outage-killed job requeues at its last checkpoint and pays
/// `resume_overhead_s` before fresh work proceeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CheckpointPolicy {
    /// Paper baseline: interrupted jobs restart from zero.
    #[default]
    None,
    /// Checkpoint every `every_s` seconds of job progress.
    Interval {
        every_s: u64,
        /// Wall seconds a resumed attempt spends restoring state
        /// before fresh work proceeds (always badput).
        resume_overhead_s: u64,
    },
}

impl CheckpointPolicy {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        match self {
            CheckpointPolicy::None => Json::from("none"),
            CheckpointPolicy::Interval { every_s, resume_overhead_s } => {
                let mut i = Json::obj();
                i.set("every_s", Json::from(*every_s));
                i.set(
                    "resume_overhead_s",
                    Json::from(*resume_overhead_s),
                );
                let mut o = Json::obj();
                o.set("interval", i);
                o
            }
        }
    }

    /// Shared validation of the three checkpoint knobs as they appear
    /// in campaign TOML (`[checkpoint]`) and sweep-matrix scenario
    /// tables — one decision table, two parsers.  `Ok(None)` means no
    /// knob was present (leave the current policy alone); `ctx`
    /// prefixes error messages.
    pub fn from_knobs(
        disabled: bool,
        every_s: Option<u64>,
        resume_overhead_s: Option<u64>,
        ctx: &str,
    ) -> Result<Option<CheckpointPolicy>, String> {
        match (disabled, every_s, resume_overhead_s) {
            (true, None, None) => Ok(Some(CheckpointPolicy::None)),
            (true, _, _) => Err(format!(
                "{ctx} sets the disabled knob next to interval knobs; \
                 pick one"
            )),
            (false, Some(0), _) => Err(format!(
                "{ctx} checkpoint interval must be >= 1 second"
            )),
            (false, Some(every_s), overhead) => {
                Ok(Some(CheckpointPolicy::Interval {
                    every_s,
                    resume_overhead_s: overhead
                        .unwrap_or(DEFAULT_RESUME_OVERHEAD_S),
                }))
            }
            (false, None, Some(_)) => Err(format!(
                "{ctx} resume overhead needs a checkpoint interval"
            )),
            (false, None, None) => Ok(None),
        }
    }

    /// Restore cost charged at the start of a resumed attempt.
    pub fn resume_overhead_s(&self) -> u64 {
        match self {
            CheckpointPolicy::None => 0,
            CheckpointPolicy::Interval { resume_overhead_s, .. } => {
                *resume_overhead_s
            }
        }
    }

    /// Largest checkpointed progress not exceeding `progress_s`.
    pub fn salvageable(&self, progress_s: u64) -> u64 {
        match self {
            CheckpointPolicy::None => 0,
            CheckpointPolicy::Interval { every_s, .. } => {
                crate::workload::icecube::salvageable_progress(
                    progress_s, *every_s,
                )
            }
        }
    }
}

/// NAT behaviour override applied to every cloud region (scenario knob).
///
/// The paper's §IV incident hinges on Azure's default 4-minute NAT idle
/// timeout; sweeps use this to ask "what if the infrastructure had been
/// different" instead of only "what if our keepalive had been different".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NatOverride {
    /// Keep each provider's own NAT profile (Azure: 240 s idle timeout).
    #[default]
    ProviderDefault,
    /// Force an idle timeout of this many seconds on every region.
    IdleTimeout(u64),
    /// No NAT idle expiry anywhere (the fixed-infrastructure ablation).
    Disabled,
}

impl NatOverride {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        match self {
            NatOverride::ProviderDefault => Json::from("provider-default"),
            NatOverride::Disabled => Json::from("disabled"),
            NatOverride::IdleTimeout(t) => {
                let mut o = Json::obj();
                o.set("idle_timeout_s", Json::from(*t));
                o
            }
        }
    }
}

/// Everything the campaign runner needs.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub seed: u64,
    pub duration_s: SimTime,
    pub tick_s: u64,
    pub sample_every_s: u64,
    /// Group/ledger/target reconciliation period.
    pub control_period_s: u64,
    pub negotiation_period_s: u64,

    pub budget_usd: f64,
    pub alert_thresholds: Vec<f64>,
    /// Non-instance costs (egress, disks, the CE VM) as a fraction of
    /// instance spend — the gap between GPU-hours x price and the paper's
    /// "all included" $58k.
    pub overhead_fraction: f64,
    /// Stop provisioning when remaining budget falls below this fraction.
    pub budget_reserve_fraction: f64,
    /// Resume after an outage at `post_outage_target` if the remaining
    /// budget fraction is at or below this (the paper's 1k-GPU decision).
    pub low_budget_resume_fraction: f64,
    pub post_outage_target: u32,

    /// Cloud worker keepalive (60 s = the post-incident tuned value;
    /// set 300 to re-live §IV).
    pub keepalive_s: u64,
    /// Multiplier on every region's baseline churn-preemption hazard
    /// (1.0 = the calibrated defaults; scenario sweeps raise it to model
    /// busier spot markets).
    pub preempt_multiplier: f64,
    /// NAT behaviour override applied to every region.
    pub nat_override: NatOverride,
    /// Job checkpoint/restart policy (None = the paper's
    /// restart-from-scratch baseline).
    pub checkpoint: CheckpointPolicy,
    /// GPU slots carved from each cloud instance (arXiv:2205.09232's
    /// fractional-GPU accounting): busy-hours are booked per *slot*,
    /// so N slots sharing one instance each accrue 1/N of its hours.
    /// 1 = the paper's whole-GPU baseline.
    pub gpu_slots_per_instance: u32,
    /// Checkpoint image size in GB; restores pay a network transfer on
    /// top of `resume_overhead_s` (see
    /// [`Self::checkpoint_transfer_s`]).  0 = transfer-free restores.
    pub checkpoint_size_gb: f64,
    /// Bandwidth available for checkpoint restores, megabit/s.
    pub checkpoint_transfer_mbps: f64,

    pub ramp: Vec<RampStep>,
    pub outage: Option<OutageSpec>,
    pub policy: PolicyMode,

    pub onprem: OnPremConfig,
    pub generator: GeneratorConfig,
    /// fp32 FLOPs per photon bunch (overridden from artifact metadata
    /// when real compute is enabled).
    pub flops_per_bunch: f64,
    pub real_compute: Option<RealComputeConfig>,
    /// Batched photon-engine execution knobs (wall time only; never
    /// part of the cache key).
    pub engine: EngineConfig,
}

impl Default for CampaignConfig {
    /// The paper's two-week exercise.
    fn default() -> Self {
        CampaignConfig {
            seed: 20210921,
            duration_s: 14 * DAY,
            tick_s: MINUTE,
            sample_every_s: 10 * MINUTE,
            control_period_s: 5 * MINUTE,
            negotiation_period_s: 5 * MINUTE,
            budget_usd: 58_000.0,
            alert_thresholds: vec![0.75, 0.5, 0.25, 0.1],
            overhead_fraction: 0.18,
            budget_reserve_fraction: 0.02,
            low_budget_resume_fraction: 0.25,
            post_outage_target: 1000,
            keepalive_s: 60,
            preempt_multiplier: 1.0,
            nat_override: NatOverride::ProviderDefault,
            checkpoint: CheckpointPolicy::None,
            gpu_slots_per_instance: 1,
            checkpoint_size_gb: 0.0,
            checkpoint_transfer_mbps: 1000.0,
            ramp: vec![
                // initial validation with a small fleet, then the paper's
                // 400 / 900 / 1.2k / 1.6k / 2k staircase
                RampStep { target: 50, hold_s: DAY },
                RampStep { target: 400, hold_s: 2 * DAY },
                RampStep { target: 900, hold_s: 2 * DAY },
                RampStep { target: 1200, hold_s: 2 * DAY },
                RampStep { target: 1600, hold_s: 2 * DAY },
                RampStep { target: 2000, hold_s: 30 * DAY }, // until outage
            ],
            outage: Some(OutageSpec {
                at_s: 11 * DAY + 6 * HOUR,
                duration_s: 2 * HOUR,
            }),
            policy: PolicyMode::Fixed(ProviderWeights {
                aws: 0.15,
                gcp: 0.15,
                azure: 0.70,
            }),
            onprem: OnPremConfig::default(),
            generator: GeneratorConfig::default(),
            flops_per_bunch: 1.2e10,
            real_compute: None,
            engine: EngineConfig::default(),
        }
    }
}

/// Convert a spec-file duration expressed in `unit_s`-second units
/// (days, hours) to whole sim-seconds.  `f64 as u64` saturates NaN and
/// negatives to 0 and +inf to `u64::MAX`, so `duration_days = -1.0`
/// would replay a zero-length campaign under a citable name; reject
/// everything the cast would corrupt instead.  Shared by
/// [`CampaignConfig::apply_toml`], the scenario-spec parser
/// (`sweep::matrix`) and the `--days` CLI override.
pub fn spec_seconds(
    v: f64,
    unit_s: u64,
    ctx: &str,
) -> Result<u64, String> {
    if !v.is_finite() || v < 0.0 {
        return Err(format!(
            "{ctx} must be a finite non-negative number (got {v})"
        ));
    }
    let s = v * unit_s as f64;
    if s >= u64::MAX as f64 {
        return Err(format!("{ctx} ({v}) is out of range"));
    }
    Ok(s as u64)
}

/// Range-check a spec-file integer destined for a `u32` field (ramp
/// targets, on-prem slots).  `u64 as u32` truncates modulo 2^32, so
/// `ramp_targets = [4294967297]` would silently "ramp" to 1 GPU.
pub fn spec_u32(v: u64, ctx: &str) -> Result<u32, String> {
    u32::try_from(v).map_err(|_| {
        format!("{ctx} ({v}) is out of range (max {})", u32::MAX)
    })
}

impl CampaignConfig {
    /// Apply a parsed TOML document on top of this config.  The knob
    /// table, the typed fetch/validation and the group resolvers all
    /// live in [`super::registry`]; see [`super::registry::KNOBS`].
    /// Strict on values: a present-but-mistyped key is an error, never
    /// a silent no-op (the server feeds untrusted `[base]` tables
    /// through here).
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        super::registry::apply_campaign_toml(self, doc)
    }

    /// Seconds to pull a checkpoint image back over the network on
    /// resume: `size_gb` gigabytes at `transfer_mbps` megabit/s
    /// (PNRP 2023 / arXiv:2308.07999 model — restore cost scales with
    /// image size, not with lost compute).  0 when the image is free
    /// to move (size 0) or the bandwidth model is degenerate.
    pub fn checkpoint_transfer_s(&self) -> u64 {
        let s = self.checkpoint_size_gb * 8000.0
            / self.checkpoint_transfer_mbps;
        if !s.is_finite() || s <= 0.0 {
            return 0;
        }
        s.ceil() as u64
    }

    /// The checkpoint policy the simulator should actually run:
    /// [`Self::checkpoint`] with the network transfer time folded into
    /// the per-resume overhead.  This is the single hook through which
    /// `checkpoint_size_gb`/`checkpoint_transfer_mbps` reach the
    /// goodput ledger — `condor::schedd` charges `resume_overhead_s`
    /// into wasted hours on every resumed attempt.
    pub fn effective_checkpoint(&self) -> CheckpointPolicy {
        let transfer_s = self.checkpoint_transfer_s();
        match self.checkpoint {
            CheckpointPolicy::Interval {
                every_s,
                resume_overhead_s,
            } if transfer_s > 0 => CheckpointPolicy::Interval {
                every_s,
                resume_overhead_s: resume_overhead_s
                    .saturating_add(transfer_s),
            },
            other => other,
        }
    }

    /// Canonical serialization: every semantically-relevant field, in a
    /// deterministic key order (`Json::Obj` is a `BTreeMap`), with
    /// deterministic number formatting (`util::json::write_num`).  Two
    /// configs produce the same string iff they replay the same
    /// campaign, which is what makes the server's content-addressed
    /// result cache sound — see `crate::server::cache`.
    ///
    /// Adding a field to `CampaignConfig` that affects the replay MUST
    /// be mirrored here; the version tag lets the cache key change
    /// shape without aliasing old keys.  [`EngineConfig`] is the one
    /// deliberate omission: the batched engine is bit-identical across
    /// its knobs, so they must NOT split the cache.
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        // v2: adds the `checkpoint` policy (PR 5); the bump keeps every
        // pre-checkpoint cache key from aliasing a v2 key
        o.set("v", Json::from(2u64));
        o.set("seed", Json::from(self.seed));
        o.set("duration_s", Json::from(self.duration_s));
        o.set("tick_s", Json::from(self.tick_s));
        o.set("sample_every_s", Json::from(self.sample_every_s));
        o.set("control_period_s", Json::from(self.control_period_s));
        o.set(
            "negotiation_period_s",
            Json::from(self.negotiation_period_s),
        );
        o.set("budget_usd", Json::from(self.budget_usd));
        o.set(
            "alert_thresholds",
            Json::Arr(
                self.alert_thresholds
                    .iter()
                    .map(|&t| Json::from(t))
                    .collect(),
            ),
        );
        o.set("overhead_fraction", Json::from(self.overhead_fraction));
        o.set(
            "budget_reserve_fraction",
            Json::from(self.budget_reserve_fraction),
        );
        o.set(
            "low_budget_resume_fraction",
            Json::from(self.low_budget_resume_fraction),
        );
        o.set(
            "post_outage_target",
            Json::from(self.post_outage_target as u64),
        );
        o.set("keepalive_s", Json::from(self.keepalive_s));
        o.set(
            "preempt_multiplier",
            Json::from(self.preempt_multiplier),
        );
        o.set("nat_override", self.nat_override.canonical_json());
        o.set("checkpoint", self.checkpoint.canonical_json());
        // PR 10 knobs: emitted only when off their defaults, so every
        // pre-existing config keeps its exact pre-PR-10 bytes (and
        // cache key) — registering a knob must never invalidate the
        // result cache.  `from_canonical_json` mirrors this with a
        // documented absent-means-default exception to its strictness.
        if self.gpu_slots_per_instance != 1 {
            o.set(
                "gpu_slots_per_instance",
                Json::from(self.gpu_slots_per_instance as u64),
            );
        }
        if self.checkpoint_size_gb != 0.0 {
            o.set(
                "checkpoint_size_gb",
                Json::from(self.checkpoint_size_gb),
            );
        }
        if self.checkpoint_transfer_mbps != 1000.0 {
            o.set(
                "checkpoint_transfer_mbps",
                Json::from(self.checkpoint_transfer_mbps),
            );
        }
        o.set(
            "ramp",
            Json::Arr(self.ramp.iter().map(RampStep::canonical_json).collect()),
        );
        o.set(
            "outage",
            match &self.outage {
                None => Json::Null,
                Some(spec) => spec.canonical_json(),
            },
        );
        o.set("policy", self.policy.canonical_json());
        let mut onprem = Json::obj();
        onprem.set("slots", Json::from(self.onprem.slots as u64));
        onprem.set("keepalive_s", Json::from(self.onprem.keepalive_s));
        onprem.set("availability", Json::from(self.onprem.availability));
        o.set("onprem", onprem);
        let mut generator = Json::obj();
        generator.set(
            "backlog_factor",
            Json::from(self.generator.backlog_factor),
        );
        generator.set(
            "min_backlog",
            Json::from(self.generator.min_backlog as u64),
        );
        generator.set(
            "request_memory_mb",
            Json::from(self.generator.request_memory_mb),
        );
        let mut runtimes = Json::obj();
        runtimes.set("median_s", Json::from(self.generator.runtimes.median_s));
        runtimes.set("sigma", Json::from(self.generator.runtimes.sigma));
        runtimes.set("min_s", Json::from(self.generator.runtimes.min_s));
        runtimes.set("max_s", Json::from(self.generator.runtimes.max_s));
        generator.set("runtimes", runtimes);
        o.set("generator", generator);
        o.set("flops_per_bunch", Json::from(self.flops_per_bunch));
        o.set(
            "real_compute",
            match &self.real_compute {
                None => Json::Null,
                Some(rc) => {
                    let mut r = Json::obj();
                    r.set("variant", Json::from(rc.variant.as_str()));
                    r.set(
                        "every_n_completions",
                        Json::from(rc.every_n_completions),
                    );
                    r
                }
            },
        );
        o
    }

    /// Inverse of [`canonical_json`](Self::canonical_json):
    /// reconstruct a replaying config from its canonical form.  This
    /// is how fleet workers receive their unit of work — the
    /// coordinator sends the *applied* config's canonical JSON in a
    /// lease grant, and because the canonical form covers every
    /// replay-relevant field, the worker's replay is byte-identical to
    /// the coordinator's.  Strict: a missing or mistyped field is an
    /// error, never a silent default — a worker replaying a different
    /// campaign than leased would fail every sha compare.
    ///
    /// [`EngineConfig`] is deliberately absent from the canonical form
    /// (results are engine-thread-invariant), so the worker keeps its
    /// own engine defaults and clamps its own thread budget.
    pub fn from_canonical_json(doc: &Json) -> Result<Self, String> {
        fn canon<'a>(doc: &'a Json, key: &str) -> Result<&'a Json, String> {
            doc.get(key)
                .ok_or_else(|| format!("canonical config missing '{key}'"))
        }
        fn canon_u64(doc: &Json, key: &str) -> Result<u64, String> {
            require_u64(canon(doc, key)?, &format!("canonical '{key}'"))
        }
        fn canon_f64(doc: &Json, key: &str) -> Result<f64, String> {
            require_f64(canon(doc, key)?, &format!("canonical '{key}'"))
        }
        fn canon_u32(doc: &Json, key: &str) -> Result<u32, String> {
            let v = canon_u64(doc, key)?;
            u32::try_from(v)
                .map_err(|_| format!("canonical '{key}' {v} is out of range"))
        }
        fn canon_i64(doc: &Json, key: &str) -> Result<i64, String> {
            let v = canon_f64(doc, key)?;
            if v.fract() != 0.0 || !(-9e15..=9e15).contains(&v) {
                return Err(format!("canonical '{key}' must be an integer"));
            }
            Ok(v as i64)
        }

        let v = canon_u64(doc, "v")?;
        if v != 2 {
            return Err(format!("unsupported canonical config version {v}"));
        }
        let mut c = CampaignConfig::default();
        c.seed = canon_u64(doc, "seed")?;
        c.duration_s = canon_u64(doc, "duration_s")?;
        c.tick_s = canon_u64(doc, "tick_s")?;
        c.sample_every_s = canon_u64(doc, "sample_every_s")?;
        c.control_period_s = canon_u64(doc, "control_period_s")?;
        c.negotiation_period_s = canon_u64(doc, "negotiation_period_s")?;
        c.budget_usd = canon_f64(doc, "budget_usd")?;
        let alerts = canon(doc, "alert_thresholds")?
            .as_arr()
            .ok_or("canonical 'alert_thresholds' must be an array")?;
        c.alert_thresholds = alerts
            .iter()
            .map(|a| {
                a.as_f64().ok_or_else(|| {
                    "canonical 'alert_thresholds' entries must be numbers"
                        .to_string()
                })
            })
            .collect::<Result<_, _>>()?;
        c.overhead_fraction = canon_f64(doc, "overhead_fraction")?;
        c.budget_reserve_fraction = canon_f64(doc, "budget_reserve_fraction")?;
        c.low_budget_resume_fraction =
            canon_f64(doc, "low_budget_resume_fraction")?;
        c.post_outage_target = canon_u32(doc, "post_outage_target")?;
        c.keepalive_s = canon_u64(doc, "keepalive_s")?;
        c.preempt_multiplier = canon_f64(doc, "preempt_multiplier")?;
        c.nat_override = match canon(doc, "nat_override")? {
            Json::Str(s) if s == "provider-default" => {
                NatOverride::ProviderDefault
            }
            Json::Str(s) if s == "disabled" => NatOverride::Disabled,
            v @ Json::Obj(_) => {
                NatOverride::IdleTimeout(canon_u64(v, "idle_timeout_s")?)
            }
            _ => return Err("canonical 'nat_override' is malformed".into()),
        };
        c.checkpoint = match canon(doc, "checkpoint")? {
            Json::Str(s) if s == "none" => CheckpointPolicy::None,
            v @ Json::Obj(_) => {
                let i = v
                    .get("interval")
                    .ok_or("canonical 'checkpoint' is malformed")?;
                CheckpointPolicy::Interval {
                    every_s: canon_u64(i, "every_s")?,
                    resume_overhead_s: canon_u64(i, "resume_overhead_s")?,
                }
            }
            _ => return Err("canonical 'checkpoint' is malformed".into()),
        };
        // default-omitted knobs (see canonical_json): absence means
        // the default — the one documented exception to the
        // missing-field-is-an-error rule.  Presence still parses
        // strictly.
        if let Some(v) = doc.get("gpu_slots_per_instance") {
            let v = require_u64(v, "canonical 'gpu_slots_per_instance'")?;
            c.gpu_slots_per_instance = u32::try_from(v).map_err(|_| {
                format!("canonical 'gpu_slots_per_instance' {v} is out of range")
            })?;
        }
        if let Some(v) = doc.get("checkpoint_size_gb") {
            c.checkpoint_size_gb =
                require_f64(v, "canonical 'checkpoint_size_gb'")?;
        }
        if let Some(v) = doc.get("checkpoint_transfer_mbps") {
            c.checkpoint_transfer_mbps =
                require_f64(v, "canonical 'checkpoint_transfer_mbps'")?;
        }
        let ramp = canon(doc, "ramp")?
            .as_arr()
            .ok_or("canonical 'ramp' must be an array")?;
        c.ramp = ramp
            .iter()
            .map(|step| {
                Ok(RampStep {
                    target: canon_u32(step, "target")?,
                    hold_s: canon_u64(step, "hold_s")?,
                })
            })
            .collect::<Result<_, String>>()?;
        c.outage = match canon(doc, "outage")? {
            Json::Null => None,
            v => Some(OutageSpec {
                at_s: canon_u64(v, "at_s")?,
                duration_s: canon_u64(v, "duration_s")?,
            }),
        };
        c.policy = match canon(doc, "policy")? {
            Json::Str(s) if s == "adaptive" => PolicyMode::Adaptive,
            Json::Str(s) if s == "risk-aware" => PolicyMode::RiskAware,
            v @ Json::Obj(_) => {
                let f =
                    v.get("fixed").ok_or("canonical 'policy' is malformed")?;
                PolicyMode::Fixed(ProviderWeights {
                    aws: canon_f64(f, "aws")?,
                    gcp: canon_f64(f, "gcp")?,
                    azure: canon_f64(f, "azure")?,
                })
            }
            _ => return Err("canonical 'policy' is malformed".into()),
        };
        let onprem = canon(doc, "onprem")?;
        c.onprem.slots = canon_u32(onprem, "slots")?;
        c.onprem.keepalive_s = canon_u64(onprem, "keepalive_s")?;
        c.onprem.availability = canon_f64(onprem, "availability")?;
        let generator = canon(doc, "generator")?;
        c.generator.backlog_factor = canon_f64(generator, "backlog_factor")?;
        c.generator.min_backlog = canon_u64(generator, "min_backlog")? as usize;
        c.generator.request_memory_mb =
            canon_i64(generator, "request_memory_mb")?;
        let runtimes = canon(generator, "runtimes")?;
        c.generator.runtimes.median_s = canon_f64(runtimes, "median_s")?;
        c.generator.runtimes.sigma = canon_f64(runtimes, "sigma")?;
        c.generator.runtimes.min_s = canon_u64(runtimes, "min_s")?;
        c.generator.runtimes.max_s = canon_u64(runtimes, "max_s")?;
        c.flops_per_bunch = canon_f64(doc, "flops_per_bunch")?;
        c.real_compute = match canon(doc, "real_compute")? {
            Json::Null => None,
            v => Some(RealComputeConfig {
                variant: v
                    .get("variant")
                    .and_then(Json::as_str)
                    .ok_or("canonical 'real_compute.variant' must be a string")?
                    .to_string(),
                every_n_completions: canon_u64(v, "every_n_completions")?,
            }),
        };
        Ok(c)
    }

    /// Build from an already-parsed TOML document over the defaults.
    pub fn from_toml_doc(doc: &Json) -> Result<Self, String> {
        let mut cfg = CampaignConfig::default();
        cfg.apply_toml(doc)?;
        Ok(cfg)
    }

    /// Load from a TOML file over the defaults.
    pub fn from_toml_file(path: &str) -> Result<Self, String> {
        Self::from_toml_doc(&load_toml_doc(path)?)
    }

    /// Total ticks in the campaign.
    pub fn num_ticks(&self) -> u64 {
        self.duration_s / self.tick_s
    }
}

/// Read and parse one TOML config file — the single loading path for
/// every `--config` consumer (campaign, sweep, serve).
pub fn load_toml_doc(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    toml::parse(&text).map_err(|e| e.to_string())
}
