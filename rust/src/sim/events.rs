//! Time-ordered event queue with deterministic FIFO tie-breaking.

use super::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, and break
        // ties by insertion order so same-time events run FIFO.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Priority queue of `(SimTime, E)` events.
///
/// Events scheduled for the same time pop in the order they were pushed,
/// which keeps replays bit-for-bit reproducible.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now if earlier).
    pub fn push_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        self.heap.push(Entry { time: at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after `delay` seconds.
    pub fn push_after(&mut self, delay: SimTime, event: E) {
        self.push_at(self.now.saturating_add(delay), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let entry = self.heap.pop()?;
        debug_assert!(entry.time >= self.now, "time must be monotonic");
        self.now = entry.time;
        Some((entry.time, entry.event))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// Recurring-tick helper: tracks the next due time of a fixed-period loop.
///
/// Control loops (negotiator cycle, reconciliation, billing, sampling) ask
/// `due(now)` and re-arm automatically; the phase offset keeps different
/// loops from all firing on the same second.
#[derive(Debug, Clone)]
pub struct Ticker {
    period: SimTime,
    next: SimTime,
}

impl Ticker {
    pub fn new(period: SimTime, phase: SimTime) -> Self {
        assert!(period > 0);
        Ticker { period, next: phase }
    }

    /// True when the loop is due at `now`; re-arms for the next period.
    /// Catches up (fires once) after a long gap rather than firing N times.
    pub fn due(&mut self, now: SimTime) -> bool {
        if now < self.next {
            return false;
        }
        // advance past `now`, skipping missed periods
        let missed = (now - self.next) / self.period;
        self.next += (missed + 1) * self.period;
        true
    }

    pub fn next_due(&self) -> SimTime {
        self.next
    }

    pub fn period(&self) -> SimTime {
        self.period
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push_at(5, "first");
        q.push_at(5, "second");
        q.push_at(5, "third");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
        assert_eq!(q.pop().unwrap().1, "third");
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(10, ());
        q.push_at(20, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 10);
        q.pop();
        assert_eq!(q.now(), 20);
    }

    #[test]
    fn push_in_past_clamps_to_now() {
        let mut q = EventQueue::new();
        q.push_at(100, "later");
        q.pop();
        q.push_at(50, "past"); // clamped to 100
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (100, "past"));
    }

    #[test]
    fn push_after_is_relative() {
        let mut q = EventQueue::new();
        q.push_at(100, "a");
        q.pop();
        q.push_after(5, "b");
        assert_eq!(q.pop().unwrap(), (105, "b"));
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.push_at(42, ());
        assert_eq!(q.peek_time(), Some(42));
        assert_eq!(q.now(), 0);
    }

    #[test]
    fn ticker_fires_on_period() {
        let mut t = Ticker::new(60, 0);
        assert!(t.due(0));
        assert!(!t.due(30));
        assert!(t.due(60));
        assert!(!t.due(61));
        assert!(t.due(120));
    }

    #[test]
    fn ticker_phase_offset() {
        let mut t = Ticker::new(60, 15);
        assert!(!t.due(0));
        assert!(t.due(15));
        assert_eq!(t.next_due(), 75);
    }

    #[test]
    fn ticker_catches_up_once_after_gap() {
        let mut t = Ticker::new(60, 0);
        assert!(t.due(0));
        // long gap: fires once, then re-arms in the future
        assert!(t.due(1000));
        assert!(!t.due(1001));
        assert_eq!(t.next_due(), 1020);
    }
}
