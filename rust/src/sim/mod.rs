//! Discrete-event simulation core.
//!
//! The campaign replay is a deterministic DES: a virtual clock in whole
//! seconds, a time-ordered event queue with FIFO tie-breaking, and a
//! recurring-tick helper for the many control loops in the stack
//! (negotiation cycles, group reconciliation, billing accrual, monitoring
//! samples).  Subsystems never read wall-clock time.

mod events;

pub use events::{EventQueue, Ticker};

/// Simulated time in whole seconds since campaign start.
pub type SimTime = u64;

/// Seconds per simulated day.
pub const DAY: SimTime = 86_400;
/// Seconds per simulated hour.
pub const HOUR: SimTime = 3_600;
/// Seconds per simulated minute.
pub const MINUTE: SimTime = 60;
