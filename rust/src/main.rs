//! `icecloud` — CLI launcher for the IceCube-in-the-clouds reproduction.
//!
//! Subcommands:
//!   campaign    run the two-week campaign (configurable)
//!   sweep       run a scenario matrix in parallel (what-if analysis);
//!               --grid expands a [grid] cartesian-product spec
//!   diff        join two sweep result files by scenario name and
//!               render per-column deltas (table/CSV/JSON)
//!   serve       HTTP scenario-sweep service with a persistent two-tier
//!               result store, async jobs and a fleet coordinator
//!               (POST /sweep [?mode=async], GET /matrix, /jobs,
//!               /jobs/<id>, /results/<key>, /metrics, /healthz,
//!               POST /fleet/{register,lease,heartbeat,complete})
//!   worker      pull-based fleet worker for a `serve` coordinator
//!   reproduce   regenerate the paper's figures/tables into a results dir
//!   validate    end-to-end smoke test of the AOT photon artifacts
//!   parity      dump per-DOM hits/summary for Python-oracle comparison
//!   info        print artifact + configuration summary
//!   knobs       print the scenario knob registry (table/json/markdown)

use icecloud::config::{spec_seconds, CampaignConfig};
use icecloud::coordinator::Campaign;
use icecloud::experiments;
use icecloud::runtime::{
    build_inputs, ExecPlan, PhotonEngine, PhotonExecutable, SimdMode,
    VariantMeta,
};
use icecloud::util::cli::Command;
use icecloud::util::json::Json;
use icecloud::util::logger;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn artifact_dir() -> PathBuf {
    std::env::var("ICECLOUD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "campaign" => cmd_campaign(rest),
        "sweep" => cmd_sweep(rest),
        "diff" => cmd_diff(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "reproduce" => cmd_reproduce(rest),
        "validate" => cmd_validate(rest),
        "parity" => cmd_parity(rest),
        "info" => cmd_info(rest),
        "knobs" => cmd_knobs(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try 'icecloud help')")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "icecloud — reproduction of 'Expanding IceCube GPU computing into \
         the Clouds' (eScience 2021)\n\n\
         usage: icecloud <command> [options]\n\n\
         commands:\n\
         \x20 campaign    run the two-week multi-cloud campaign\n\
         \x20 sweep       run a scenario matrix in parallel (what-if \
         analysis; --grid for cartesian-product specs)\n\
         \x20 diff        per-column deltas between two sweep result \
         files (sweep.json or /results/<key> bodies)\n\
         \x20 serve       HTTP sweep service with a persistent result \
         store, async jobs and a fleet coordinator\n\
         \x20 worker      pull-based fleet worker (--coordinator \
         host:port)\n\
         \x20 reproduce   regenerate paper figures/tables (--all, --fig1, \
         --fig2, --headline, --nat, --ramp)\n\
         \x20 validate    end-to-end smoke test of the photon artifacts\n\
         \x20 parity      per-DOM hits/summary JSON for oracle comparison \
         (tools/parity_check.py)\n\
         \x20 info        artifact and configuration summary\n\
         \x20 knobs       scenario knob registry (--format \
         table|json|markdown)\n\
         \x20 help        this message\n"
    );
}

fn campaign_command() -> Command {
    Command::new("campaign", "run the two-week multi-cloud campaign")
        .opt("config", "TOML config file", None)
        .opt("seed", "override RNG seed", None)
        .opt("days", "override campaign duration (days)", None)
        .opt("keepalive", "worker keepalive seconds (300 = relive §IV)", None)
        .opt(
            "engine-threads",
            "photon-engine threads per bunch (0 = all cores)",
            None,
        )
        .opt(
            "engine-simd",
            "photon-engine segment sweep: lanes|off (default lanes)",
            None,
        )
        .opt("out", "write monitoring CSV + summary into this directory", None)
        .opt("log", "log level: debug|info|warn|error", Some("info"))
        .flag("real-compute", "sample real PJRT photon executions")
        .flag("no-outage", "disable the day-11 CE outage")
}

fn load_config(args: &icecloud::util::cli::Args) -> Result<CampaignConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => CampaignConfig::from_toml_file(path)?,
        None => CampaignConfig::default(),
    };
    if let Some(seed) = args.get_u64("seed") {
        cfg.seed = seed;
    }
    if let Some(days) = args.get_f64("days") {
        cfg.duration_s = spec_seconds(days, 86_400, "--days")?;
    }
    if let Some(k) = args.get_u64("keepalive") {
        cfg.keepalive_s = k;
    }
    if let Some(t) = args.require_u64("engine-threads")? {
        cfg.engine.threads = u32::try_from(t)
            .map_err(|_| format!("--engine-threads {t} is out of range"))?;
    }
    apply_engine_simd(args, &mut cfg)?;
    if args.flag("no-outage") {
        cfg.outage = None;
    }
    if args.flag("real-compute") {
        cfg.real_compute = Some(icecloud::config::RealComputeConfig {
            variant: "default".into(),
            every_n_completions: 200,
        });
    }
    Ok(cfg)
}

fn cmd_campaign(rest: &[String]) -> Result<(), String> {
    let cmd = campaign_command();
    let args = cmd.parse(rest)?;
    if let Some(level) = logger::level_from_str(args.get_or("log", "info")) {
        logger::set_level(level);
    }
    let cfg = load_config(&args)?;
    let engine_exe = if cfg.real_compute.is_some() {
        let engine = PhotonEngine::new(&artifact_dir()).map_err(|e| e.to_string())?;
        let variant = cfg.real_compute.as_ref().unwrap().variant.clone();
        Some(engine.compile(&variant).map_err(|e| e.to_string())?)
    } else {
        None
    };

    println!(
        "running campaign: {} days, seed {}, keepalive {} s, outage {}",
        cfg.duration_s as f64 / 86_400.0,
        cfg.seed,
        cfg.keepalive_s,
        cfg.outage.is_some()
    );
    let t0 = std::time::Instant::now();
    let result = Campaign::with_engine(cfg, engine_exe).run();
    println!("campaign replay took {:.2?} wall", t0.elapsed());
    print_summary(&result);

    if let Some(out) = args.get("out") {
        let dir = Path::new(out);
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let csv = result.monitor.to_csv(&[
            "gpus.total",
            "gpus.azure",
            "gpus.gcp",
            "gpus.aws",
            "jobs.idle",
            "jobs.running",
            "budget.spent",
        ]);
        std::fs::write(dir.join("monitoring.csv"), csv).map_err(|e| e.to_string())?;
        let headline = icecloud::experiments::headline::extract(&result);
        std::fs::write(
            dir.join("summary.json"),
            headline.to_json().to_string_pretty(),
        )
        .map_err(|e| e.to_string())?;
        println!("wrote {}/monitoring.csv and summary.json", dir.display());
    }
    Ok(())
}

fn print_summary(result: &icecloud::coordinator::CampaignResult) {
    let h = icecloud::experiments::headline::extract(result);
    println!("{}", h.table());
    if result.real_compute.bunches > 0 {
        let rc = result.real_compute;
        println!(
            "real compute: {} bunches, {} photons, {:.0} detected, \
             {:.1} s wall, {:.2} Mphotons/s, {:.2} GFLOP/s",
            rc.bunches,
            rc.photons,
            rc.detected,
            rc.wall_s,
            rc.photons_per_sec() / 1e6,
            rc.flops_per_sec() / 1e9
        );
    }
}

/// Base campaign for sweep-style commands (`sweep`, `serve`).
/// Precedence (weakest to strongest): 4-day default < `--config` file;
/// the caller layers anything stronger (matrix `[base]`, `--days`) via
/// [`apply_days_override`] afterwards.  Sweeps compare many replays, so
/// the default is a responsive 4-day slice rather than the full window.
/// Also returns the parsed `--config` document (when there is one) so
/// `serve` can read its `[server]` table from the same file without a
/// second resolution path.
fn sweep_base_config(
    args: &icecloud::util::cli::Args,
) -> Result<(CampaignConfig, Option<Json>), String> {
    match args.get("config") {
        Some(path) => {
            let doc = icecloud::config::load_toml_doc(path)?;
            Ok((CampaignConfig::from_toml_doc(&doc)?, Some(doc)))
        }
        None => {
            let mut cfg = CampaignConfig::default();
            cfg.duration_s = 4 * 86_400;
            Ok((cfg, None))
        }
    }
}

/// The strongest duration override: an explicit `--days`.
fn apply_days_override(
    args: &icecloud::util::cli::Args,
    base: &mut CampaignConfig,
) -> Result<(), String> {
    if let Some(days) = args.get_f64("days") {
        base.duration_s = spec_seconds(days, 86_400, "--days")?;
    }
    Ok(())
}

/// `--engine-simd lanes|off`: strongest override of the segment-sweep
/// knob (over `[engine] simd` from the config file).  Wall-time only —
/// both values replay bit-identically — so, like `engine.threads`, it
/// never enters the campaign cache key.
fn apply_engine_simd(
    args: &icecloud::util::cli::Args,
    base: &mut CampaignConfig,
) -> Result<(), String> {
    if let Some(v) = args.get("engine-simd") {
        base.engine.simd = SimdMode::parse(v).ok_or_else(|| {
            format!("--engine-simd must be \"lanes\" or \"off\", got {v:?}")
        })?;
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new("sweep", "run a scenario matrix in parallel")
        .opt(
            "matrix",
            "TOML matrix spec ([scenario.<name>] tables and/or [grid]; \
             default: the built-in 10-scenario matrix)",
            None,
        )
        .opt(
            "grid",
            "TOML grid spec (requires a [grid] section of per-axis value \
             lists; expands to the cartesian product)",
            None,
        )
        .opt("config", "base campaign TOML (defaults to the paper setup)", None)
        .opt(
            "days",
            "base campaign duration in days (default 4; use 14 for the \
             paper's full window)",
            None,
        )
        .opt("threads", "worker threads (default: available parallelism)", None)
        .opt(
            "engine-simd",
            "photon-engine segment sweep: lanes|off (default lanes)",
            None,
        )
        .opt("out", "write sweep.csv / sweep.txt / rollup.txt here", None)
        .opt("log", "log level: debug|info|warn|error", Some("error"));
    let args = cmd.parse(rest)?;
    if let Some(level) = logger::level_from_str(args.get_or("log", "error")) {
        logger::set_level(level);
    }

    // precedence (weakest to strongest):
    // 4-day default < --config file < matrix [base] < explicit --days
    let (mut base, _doc) = sweep_base_config(&args)?;
    let scenarios = match (args.get("matrix"), args.get("grid")) {
        (Some(_), Some(_)) => {
            return Err(
                "--matrix and --grid are exclusive; a --matrix spec may \
                 itself carry a [grid] section"
                    .into(),
            )
        }
        (Some(path), None) => {
            icecloud::sweep::matrix::from_toml_file(path, &mut base)?
        }
        (None, Some(path)) => {
            // same file format and parse path as --matrix, but the
            // caller is asserting a cartesian product: a spec without
            // [grid] is a mistake, not a 1-scenario sweep
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("cannot read {path}: {e}"))?;
            let doc = icecloud::util::toml::parse(&text)
                .map_err(|e| e.to_string())?;
            if doc.get("grid").is_none() {
                return Err(format!(
                    "--grid spec {path} has no [grid] section"
                ));
            }
            icecloud::sweep::parse_spec_json(&doc, &mut base)?
        }
        (None, None) => icecloud::sweep::builtin_matrix(),
    };
    apply_days_override(&args, &mut base)?;
    apply_engine_simd(&args, &mut base)?;
    let threads = args
        .get_u64("threads")
        .map(|t| t as usize)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });

    println!(
        "sweep: {} scenarios x {:.1} sim-days on {} threads",
        scenarios.len(),
        base.duration_s as f64 / 86_400.0,
        threads.max(1).min(scenarios.len().max(1)),
    );
    let t0 = std::time::Instant::now();
    let rows = icecloud::sweep::run_matrix(&base, &scenarios, threads);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} replays in {:.2} s wall ({:.2} replays/s)\n",
        rows.len(),
        wall,
        rows.len() as f64 / wall.max(1e-9),
    );
    print!("{}", icecloud::experiments::sweep::render(&rows));

    if let Some(out) = args.get("out") {
        icecloud::experiments::sweep::write(&rows, Path::new(out))
            .map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn cmd_diff(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "diff",
        "join two sweep result files by scenario name and render \
         per-column deltas (delta = B - A)",
    )
    .opt("format", "table|csv|json", Some("table"))
    .opt("out", "write the rendering here instead of stdout", None);
    let args = cmd.parse(rest)?;
    let [a_path, b_path] = args.positional() else {
        return Err(
            "usage: icecloud diff <a.json> <b.json> [--format \
             table|csv|json] [--out <file>]  (inputs: sweep.json files \
             or saved /results/<key> bodies)"
                .into(),
        );
    };
    let read = |path: &str| -> Result<experiments::diff::Rows, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path}: {e}"))?;
        experiments::diff::parse_rows(&text)
            .map_err(|e| format!("{path}: {e}"))
    };
    let a = read(a_path)?;
    let b = read(b_path)?;
    let d = experiments::diff::diff(&a, &b);
    let rendered = match args.get_or("format", "table") {
        "table" => experiments::diff::render(&d),
        "csv" => experiments::diff::to_csv(&d),
        "json" => {
            let mut s = experiments::diff::to_json(&d).to_string_pretty();
            s.push('\n');
            s
        }
        other => {
            return Err(format!(
                "--format must be table|csv|json, got {other:?}"
            ))
        }
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &rendered).map_err(|e| e.to_string())?;
            println!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "serve",
        "HTTP scenario-sweep service with a persistent content-addressed \
         result store and async jobs",
    )
    .opt("addr", "bind address", Some("127.0.0.1:8080"))
    .opt("threads", "HTTP connection-handler threads", Some("8"))
    .opt(
        "replay-threads",
        "campaign replay workers (default: available parallelism)",
        None,
    )
    .opt("cache-mb", "result-cache (memory tier) budget in MiB", None)
    .opt(
        "queue-max",
        "bounded async-job admission queue (429 beyond it)",
        None,
    )
    .opt("job-runners", "async job-runner threads", None)
    .opt(
        "store-dir",
        "persistent result-store root (\"\" = memory-only; default \
         icecloud-store)",
        None,
    )
    .opt(
        "jobs-keep",
        "finished async-job records kept for GET /jobs",
        None,
    )
    .opt(
        "events-ring",
        "live event-bus ring capacity (GET /events)",
        None,
    )
    .opt(
        "sample-every-s",
        "ops sampler cadence in seconds (GET /timeseries, /dash)",
        None,
    )
    .opt(
        "config",
        "base campaign TOML, optionally with [server], [fleet] and \
         [ops] tables",
        None,
    )
    .opt(
        "days",
        "base campaign duration in days (default 4, like `sweep`)",
        None,
    )
    .opt(
        "engine-simd",
        "photon-engine segment sweep: lanes|off (default lanes)",
        None,
    )
    .opt("lease-ttl-s", "fleet lease TTL in seconds", None)
    .opt(
        "heartbeat-every-s",
        "fleet worker heartbeat cadence in seconds",
        None,
    )
    .opt(
        "spot-check-rate",
        "fraction of fleet completions re-replayed locally [0,1]",
        None,
    )
    .opt("log", "log level: debug|info|warn|error", Some("info"));
    let args = cmd.parse(rest)?;
    if let Some(level) = logger::level_from_str(args.get_or("log", "info")) {
        logger::set_level(level);
    }

    // same base resolution as `icecloud sweep` (request bodies layer
    // their own [base] tables per request on top); serving knobs
    // resolve weakest to strongest: defaults < [server] table < flags
    let (mut base, doc) = sweep_base_config(&args)?;
    apply_days_override(&args, &mut base)?;
    apply_engine_simd(&args, &mut base)?;
    let mut srv = icecloud::config::ServerConfig::default();
    let mut fleet = icecloud::config::FleetConfig::default();
    let mut ops = icecloud::config::OpsConfig::default();
    if let Some(doc) = &doc {
        srv.apply_toml(doc)?;
        fleet.apply_toml(doc)?;
        ops.apply_toml(doc)?;
    }
    if let Some(v) = args.require_u64("queue-max")? {
        if v == 0 {
            return Err("--queue-max must be >= 1".into());
        }
        srv.queue_max = u32::try_from(v)
            .map_err(|_| format!("--queue-max {v} is out of range"))?;
    }
    if let Some(v) = args.require_u64("job-runners")? {
        if v == 0 {
            return Err("--job-runners must be >= 1".into());
        }
        srv.job_runners = u32::try_from(v)
            .map_err(|_| format!("--job-runners {v} is out of range"))?;
    }
    if let Some(v) = args.require_u64("cache-mb")? {
        if v == 0 {
            return Err("--cache-mb must be >= 1".into());
        }
        srv.cache_mb = v;
    }
    if let Some(v) = args.require_u64("jobs-keep")? {
        if v == 0 {
            return Err("--jobs-keep must be >= 1".into());
        }
        srv.jobs_keep = u32::try_from(v)
            .map_err(|_| format!("--jobs-keep {v} is out of range"))?;
    }
    if let Some(v) = args.require_u64("events-ring")? {
        if v == 0 {
            return Err("--events-ring must be >= 1".into());
        }
        ops.events_ring = u32::try_from(v)
            .map_err(|_| format!("--events-ring {v} is out of range"))?;
    }
    if let Some(v) = args.require_u64("sample-every-s")? {
        if v == 0 {
            return Err("--sample-every-s must be >= 1".into());
        }
        ops.sample_every_s = v;
    }
    let store_dir = match args.get("store-dir") {
        Some("") => None,
        Some(dir) => Some(PathBuf::from(dir)),
        None => srv.store_dir.clone().map(PathBuf::from),
    };
    if let Some(v) = args.require_u64("lease-ttl-s")? {
        if v == 0 {
            return Err("--lease-ttl-s must be >= 1".into());
        }
        fleet.lease_ttl_s = v;
    }
    if let Some(v) = args.require_u64("heartbeat-every-s")? {
        if v == 0 {
            return Err("--heartbeat-every-s must be >= 1".into());
        }
        fleet.heartbeat_every_s = v;
    }
    if let Some(v) = args.require_f64("spot-check-rate")? {
        if !(0.0..=1.0).contains(&v) {
            return Err(format!(
                "--spot-check-rate must be in [0, 1] (got {v})"
            ));
        }
        fleet.spot_check_rate = v;
    }
    if fleet.heartbeat_every_s >= fleet.lease_ttl_s {
        return Err(format!(
            "heartbeat cadence ({} s) must be shorter than the lease \
             TTL ({} s) or every lease expires between beats",
            fleet.heartbeat_every_s, fleet.lease_ttl_s
        ));
    }

    let cfg = icecloud::server::ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        http_threads: args.get_u64("threads").unwrap_or(8) as usize,
        replay_threads: args
            .get_u64("replay-threads")
            .map(|t| t as usize)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4)
            }),
        cache_bytes: (srv.cache_mb as usize) << 20,
        queue_max: srv.queue_max as usize,
        job_runners: srv.job_runners as usize,
        store_dir: store_dir.clone(),
        fleet: icecloud::server::FleetOptions {
            lease_ttl: std::time::Duration::from_secs(fleet.lease_ttl_s),
            heartbeat_every: std::time::Duration::from_secs(
                fleet.heartbeat_every_s,
            ),
            spot_check_rate: fleet.spot_check_rate,
        },
        events_ring: ops.events_ring as usize,
        sample_every_s: ops.sample_every_s,
        jobs_keep: srv.jobs_keep as usize,
        base,
    };
    let http_threads = cfg.http_threads;
    let replay_threads = cfg.replay_threads;
    let server = icecloud::server::Server::bind(cfg)?;
    println!(
        "icecloud serve: listening on {} ({} http threads, {} replay \
         workers, {} job runners, store: {})\n  endpoints: GET /healthz \
         /matrix /metrics /jobs /jobs/<id> /results/<key> /events \
         /timeseries[/<name>] /dash /dash.json; POST /sweep \
         [?mode=async]; POST /fleet/{{register,lease,heartbeat,complete}} \
         — all also mounted under /v1/ (DESIGN.md §19)",
        server.local_addr()?,
        http_threads,
        replay_threads,
        srv.job_runners,
        match &store_dir {
            Some(dir) => dir.display().to_string(),
            None => "disabled (memory-only)".to_string(),
        },
    );
    server.run()
}

fn cmd_worker(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "worker",
        "pull-based fleet worker: lease scenario units from an `icecloud \
         serve` coordinator, replay them locally, stream rows back",
    )
    .opt("coordinator", "coordinator address (host:port); required", None)
    .opt("id", "worker id (default: worker-<pid>)", None)
    .opt("slots", "advertised concurrency", Some("1"))
    .opt("poll-ms", "idle poll interval in milliseconds", Some("500"))
    .opt(
        "fail-after-leases",
        "fault injection: vanish mid-lease after N grants (tests)",
        None,
    )
    .opt(
        "engine-simd",
        "photon-engine segment sweep: lanes|off (default lanes)",
        None,
    )
    .opt("log", "log level: debug|info|warn|error", Some("info"));
    let args = cmd.parse(rest)?;
    if let Some(level) = logger::level_from_str(args.get_or("log", "info")) {
        logger::set_level(level);
    }
    let Some(raw) = args.get("coordinator") else {
        return Err("--coordinator <host:port> is required".into());
    };
    let coordinator = raw
        .strip_prefix("http://")
        .unwrap_or(raw)
        .trim_end_matches('/')
        .to_string();
    if coordinator.is_empty() {
        return Err("--coordinator must name a host:port".into());
    }
    let worker_id = match args.get("id") {
        Some("") => return Err("--id must not be empty".into()),
        Some(id) => id.to_string(),
        None => format!("worker-{}", std::process::id()),
    };
    let slots = args.require_u64("slots")?.unwrap_or(1);
    if slots == 0 {
        return Err("--slots must be >= 1".into());
    }
    let slots = u32::try_from(slots)
        .map_err(|_| format!("--slots {slots} is out of range"))?;
    let poll_ms = args.require_u64("poll-ms")?.unwrap_or(500);
    if poll_ms == 0 {
        return Err("--poll-ms must be >= 1".into());
    }
    let engine_simd = match args.get("engine-simd") {
        Some(v) => SimdMode::parse(v).ok_or_else(|| {
            format!("--engine-simd must be \"lanes\" or \"off\", got {v:?}")
        })?,
        None => SimdMode::default(),
    };
    let opts = icecloud::server::WorkerOptions {
        coordinator,
        worker_id,
        slots,
        poll: std::time::Duration::from_millis(poll_ms),
        fail_after_leases: args.require_u64("fail-after-leases")?,
        engine_simd,
    };
    println!(
        "icecloud worker '{}' -> {} ({} slot{})",
        opts.worker_id,
        opts.coordinator,
        opts.slots,
        if opts.slots == 1 { "" } else { "s" },
    );
    let stop = std::sync::atomic::AtomicBool::new(false);
    let report = icecloud::server::fleet::run_worker(&opts, &stop)?;
    println!(
        "worker '{}' done: {} lease(s), {} completed",
        opts.worker_id, report.leases, report.completed
    );
    Ok(())
}

fn cmd_reproduce(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new("reproduce", "regenerate the paper's evaluation")
        .opt("out", "results directory", Some("results"))
        .opt("seed", "campaign seed", None)
        .flag("all", "all figures and tables")
        .flag("fig1", "Fig 1: GPU monitoring snapshot")
        .flag("fig2", "Fig 2: GPU wall-hour doubling")
        .flag("headline", "T1: cost / GPU-days / EFLOP-hours")
        .flag("nat", "§IV keepalive-vs-NAT sweep")
        .flag("ramp", "§IV validation + policy ablation");
    let args = cmd.parse(rest)?;
    let out_root = PathBuf::from(args.get_or("out", "results"));
    let all = args.flag("all")
        || !(args.flag("fig1")
            || args.flag("fig2")
            || args.flag("headline")
            || args.flag("nat")
            || args.flag("ramp"));

    let needs_campaign =
        all || args.flag("fig1") || args.flag("fig2") || args.flag("headline");
    let campaign_result = if needs_campaign {
        let mut cfg = CampaignConfig::default();
        if let Some(seed) = args.get_u64("seed") {
            cfg.seed = seed;
        }
        println!("[reproduce] running the full two-week campaign ...");
        Some(Campaign::new(cfg).run())
    } else {
        None
    };

    if all || args.flag("fig1") {
        println!("[reproduce] F1 — Fig 1 monitoring snapshot");
        let fig =
            experiments::fig1::write(campaign_result.as_ref().unwrap(), &out_root)
                .map_err(|e| e.to_string())?;
        println!("{}", fig.chart());
    }
    if all || args.flag("fig2") {
        println!("[reproduce] F2 — Fig 2 GPU wall hours");
        let fig =
            experiments::fig2::write(campaign_result.as_ref().unwrap(), &out_root)
                .map_err(|e| e.to_string())?;
        println!("{}", fig.chart());
    }
    if all || args.flag("headline") {
        println!("[reproduce] T1 — headline numbers");
        let h = experiments::headline::write(
            campaign_result.as_ref().unwrap(),
            &out_root,
        )
        .map_err(|e| e.to_string())?;
        println!("{}", h.table());
        h.check_shape()?;
        println!("  shape check: OK (azure cheapest, largest share, most stable)");
    }
    if all || args.flag("nat") {
        println!("[reproduce] NAT — keepalive sweep (6 scenarios)");
        let rows = experiments::nat::write(&out_root).map_err(|e| e.to_string())?;
        println!("{}", experiments::nat::render(&rows));
        experiments::nat::check_cliff(&rows)?;
        println!("  cliff check: OK (stable ≤240 s, storm >240 s)");
    }
    if all || args.flag("ramp") {
        println!("[reproduce] RAMP — validation + policy ablation");
        let (rows, ablation) =
            experiments::ramp::write(&out_root).map_err(|e| e.to_string())?;
        println!("{}", experiments::ramp::render(&rows, &ablation));
        experiments::ramp::check_azure_wins(&rows)?;
        println!("  shape check: OK (azure cheapest + most stable)");
    }
    println!("[reproduce] outputs in {}", out_root.display());
    Ok(())
}

fn cmd_validate(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new("validate", "photon-runtime end-to-end smoke test")
        .opt("variant", "artifact variant", Some("small"))
        .opt("bunches", "number of bunches to execute", Some("3"));
    let args = cmd.parse(rest)?;
    let engine = PhotonEngine::new(&artifact_dir()).map_err(|e| e.to_string())?;
    println!("photon runtime: {}", engine.platform());
    let variant = args.get_or("variant", "small");
    let exe = engine.compile(variant).map_err(|e| e.to_string())?;
    println!(
        "compiled variant '{}': {} photons x {} steps, {} DOMs",
        variant, exe.meta.num_photons, exe.meta.num_steps, exe.meta.num_doms
    );
    let n = args.get_u64("bunches").unwrap_or(3);
    for seed in 0..n {
        let r = exe.run_seeded(seed as u32).map_err(|e| e.to_string())?;
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        if total as u64 != exe.meta.num_photons {
            return Err(format!(
                "photon conservation violated: {total} != {}",
                exe.meta.num_photons
            ));
        }
        println!(
            "bunch seed={seed}: detected={} absorbed={} alive={} \
             ({:.1} ms, {:.2} Mphotons/s)",
            r.summary[0],
            r.summary[1],
            r.summary[2],
            r.wall_s * 1e3,
            exe.meta.num_photons as f64 / r.wall_s / 1e6
        );
    }
    println!("validate OK: artifact executes and conserves photons");
    Ok(())
}

/// Built-in shape table for `parity`, mirroring the `VARIANTS` dict in
/// `python/compile/geometry.py` so the oracle comparison needs no
/// artifact build (jax lowering) on the Rust side.
fn parity_variant(name: &str) -> Result<VariantMeta, String> {
    match name {
        "small" => Ok(VariantMeta::synthetic("small", 256, 128, 16, 16)),
        "default" => Ok(VariantMeta::synthetic("default", 4096, 512, 60, 64)),
        "large" => Ok(VariantMeta::synthetic("large", 16384, 1024, 240, 96)),
        other => Err(format!(
            "unknown parity variant '{other}' (small|default|large)"
        )),
    }
}

/// `icecloud parity` — machine-readable hits/summary for one bunch, so
/// `tools/parity_check.py` can pin the Rust engine against the Python
/// oracle (`python/compile/kernels/ref.py`) end to end.
fn cmd_parity(rest: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "parity",
        "dump per-DOM hits/summary JSON for Python-oracle comparison",
    )
    .opt("variant", "built-in shape: small|default|large", Some("small"))
    .opt("seed", "bunch seed", Some("7"))
    .opt(
        "mode",
        "scalar|batched (lane sweep off)|simd (lane sweep on)",
        Some("batched"),
    )
    .opt("threads", "batched engine threads (0 = all cores)", Some("1"))
    .opt("bunch", "photons per SoA sub-bunch (0 = default)", Some("0"));
    let args = cmd.parse(rest)?;
    let variant = args.get_or("variant", "small").to_string();
    let seed = args.require_u64("seed")?.unwrap_or(7) as u32;
    let exe = PhotonExecutable::from_meta(parity_variant(&variant)?)
        .map_err(|e| e.to_string())?;
    let inputs = build_inputs(&exe.meta, seed, true);
    let mode = args.get_or("mode", "batched").to_string();
    let simd = match mode.as_str() {
        "batched" => SimdMode::Off,
        _ => SimdMode::Lanes,
    };
    let r = match mode.as_str() {
        "scalar" => exe.run_scalar(&inputs),
        "batched" | "simd" => {
            let plan = ExecPlan {
                threads: args.require_u64("threads")?.unwrap_or(1) as usize,
                bunch: args.require_u64("bunch")?.unwrap_or(0) as usize,
                simd,
            };
            exe.run_with_plan(&inputs, plan)
        }
        other => {
            return Err(format!(
                "unknown mode '{other}' (scalar|batched|simd)"
            ))
        }
    }
    .map_err(|e| e.to_string())?;
    let mut o = Json::obj();
    o.set("variant", Json::from(variant.as_str()));
    o.set("seed", Json::from(seed as u64));
    o.set("mode", Json::from(mode.as_str()));
    o.set(
        "hits",
        Json::Arr(r.hits.iter().map(|&h| Json::from(h as f64)).collect()),
    );
    o.set(
        "summary",
        Json::Arr(r.summary.iter().map(|&v| Json::from(v as f64)).collect()),
    );
    println!("{}", o.to_string_compact());
    Ok(())
}

fn cmd_info(_rest: &[String]) -> Result<(), String> {
    let dir = artifact_dir();
    println!("artifact dir: {}", dir.display());
    match icecloud::runtime::ArtifactMeta::load(&dir) {
        Ok(meta) => {
            for v in &meta.variants {
                println!(
                    "  {}: photons={} block={} doms={} steps={} \
                     flops/bunch={:.2e} file={}",
                    v.name, v.num_photons, v.block, v.num_doms, v.num_steps,
                    v.flops_estimate, v.file
                );
            }
        }
        Err(e) => println!("  (no artifacts: {e}; run `python -m compile.aot` from python/)"),
    }
    let cfg = CampaignConfig::default();
    println!(
        "default campaign: {} days, budget ${}, ramp {:?}, outage at day {:?}",
        cfg.duration_s / 86_400,
        cfg.budget_usd,
        cfg.ramp.iter().map(|s| s.target).collect::<Vec<_>>(),
        cfg.outage.map(|o| o.at_s as f64 / 86_400.0)
    );
    Ok(())
}

fn cmd_knobs(rest: &[String]) -> Result<(), String> {
    use icecloud::config::registry;
    let cmd = Command::new(
        "knobs",
        "print the scenario knob registry (the whole sweepable surface)",
    )
    .opt("format", "output format: table|json|markdown", Some("table"));
    let args = cmd.parse(rest)?;
    match args.get_or("format", "table") {
        "table" => print!("{}", registry::render_table()),
        "markdown" => print!("{}", registry::render_markdown()),
        "json" => println!("{}", registry::render_json().to_string_compact()),
        other => {
            return Err(format!(
                "unknown --format '{other}' (expected table, json or \
                 markdown)"
            ))
        }
    }
    Ok(())
}
