//! The schedd: job queue, submission, and goodput/badput accounting.

use super::classad::{Ad, Expr};
use super::job::{Job, JobId, JobState};
use super::startd::SlotId;
use crate::sim::SimTime;
use crate::util::fxhash::FxHashMap;
use std::collections::BTreeSet;

/// Aggregate queue statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScheddStats {
    pub submitted: u64,
    pub completed: u64,
    /// Attempts lost to preemption / connection loss (job went back idle).
    pub interrupted: u64,
    /// Productive wall seconds (completed attempts).
    pub goodput_s: u64,
    /// Wasted wall seconds (interrupted attempts).
    pub badput_s: u64,
    /// fp32 FLOPs of completed jobs.
    pub flops_done: f64,
}

/// The job queue daemon.
#[derive(Debug, Default)]
pub struct Schedd {
    jobs: Vec<Job>,
    /// Idle jobs ordered by JobId (negotiation prefers older
    /// submissions; O(log n) insert/remove at campaign scale).
    idle: BTreeSet<JobId>,
    running: FxHashMap<JobId, SlotId>,
    pub stats: ScheddStats,
}

impl Schedd {
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit a job; assigns its JobId.
    pub fn submit(
        &mut self,
        owner: &str,
        runtime_s: u64,
        flops: f64,
        bunches: u32,
        ad: Ad,
        requirements: Expr,
        now: SimTime,
    ) -> JobId {
        let id = JobId(self.jobs.len() as u64);
        let autocluster = super::job::autocluster_signature(&requirements, &ad);
        self.jobs.push(Job {
            id,
            owner: owner.to_string(),
            submitted_at: now,
            runtime_s,
            flops,
            bunches,
            state: JobState::Idle,
            attempts: 0,
            started_at: None,
            completed_at: None,
            goodput_s: 0,
            badput_s: 0,
            ad,
            requirements,
            autocluster,
        });
        self.idle.insert(id);
        self.stats.submitted += 1;
        id
    }

    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn idle_count(&self) -> usize {
        self.idle.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Idle job ids in JobId order (the negotiator's input).
    pub fn idle_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.idle.iter().copied()
    }

    /// The slot a running job occupies.
    pub fn slot_of(&self, id: JobId) -> Option<SlotId> {
        self.running.get(&id).copied()
    }

    /// Transition Idle -> Running on a successful match.
    pub fn start(&mut self, id: JobId, slot: SlotId, now: SimTime) {
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Idle);
        job.state = JobState::Running;
        job.attempts += 1;
        job.started_at = Some(now);
        self.idle.remove(&id);
        self.running.insert(id, slot);
    }

    /// Transition Running -> Completed.
    pub fn complete(&mut self, id: JobId, now: SimTime) {
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.state = JobState::Completed;
        job.completed_at = Some(now);
        let wall = now.saturating_sub(job.started_at.expect("running job"));
        job.goodput_s += wall;
        self.running.remove(&id);
        self.stats.completed += 1;
        self.stats.goodput_s += wall;
        self.stats.flops_done += job.flops;
    }

    /// Transition Running -> Idle (preemption, disconnect, outage).
    /// The attempt's wall time is badput; IceCube jobs restart from scratch.
    pub fn interrupt(&mut self, id: JobId, now: SimTime) {
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.state = JobState::Idle;
        let wall = now.saturating_sub(job.started_at.expect("running job"));
        job.badput_s += wall;
        job.started_at = None;
        self.running.remove(&id);
        self.idle.insert(id);
        self.stats.interrupted += 1;
        self.stats.badput_s += wall;
    }

    /// Sanity checks used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        for id in &self.idle {
            if self.jobs[id.0 as usize].state != JobState::Idle {
                return Err(format!("{id} in idle queue but not Idle"));
            }
        }
        for (id, _) in &self.running {
            if self.jobs[id.0 as usize].state != JobState::Running {
                return Err(format!("{id} in running map but not Running"));
            }
        }
        let counted = self
            .jobs
            .iter()
            .filter(|j| j.state == JobState::Idle)
            .count();
        if counted != self.idle.len() {
            return Err(format!(
                "idle queue {} != idle jobs {counted}",
                self.idle.len()
            ));
        }
        if self.stats.completed
            != self.jobs.iter().filter(|j| j.state == JobState::Completed).count()
                as u64
        {
            return Err("completed count mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::InstanceId;
    use crate::condor::job::{gpu_job_ad, gpu_requirements};

    fn submit(s: &mut Schedd, runtime: u64) -> JobId {
        s.submit(
            "icecube",
            runtime,
            1e15,
            100,
            gpu_job_ad("icecube", 8192),
            gpu_requirements(),
            0,
        )
    }

    fn slot(n: u64) -> SlotId {
        SlotId::Cloud(InstanceId(n))
    }

    #[test]
    fn submit_enqueues_idle() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        assert_eq!(s.idle_count(), 1);
        assert_eq!(s.job(id).state, JobState::Idle);
        assert_eq!(s.stats.submitted, 1);
    }

    #[test]
    fn full_lifecycle_goodput() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        s.start(id, slot(1), 100);
        assert_eq!(s.running_count(), 1);
        assert_eq!(s.slot_of(id), Some(slot(1)));
        s.complete(id, 3700);
        assert_eq!(s.job(id).state, JobState::Completed);
        assert_eq!(s.job(id).goodput_s, 3600);
        assert_eq!(s.stats.goodput_s, 3600);
        assert_eq!(s.stats.flops_done, 1e15);
        s.check_invariants().unwrap();
    }

    #[test]
    fn interrupt_accrues_badput_and_requeues() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        s.start(id, slot(1), 0);
        s.interrupt(id, 1800); // preempted halfway
        assert_eq!(s.job(id).state, JobState::Idle);
        assert_eq!(s.job(id).badput_s, 1800);
        assert_eq!(s.idle_count(), 1);
        assert_eq!(s.stats.interrupted, 1);
        // second attempt succeeds
        s.start(id, slot(2), 2000);
        s.complete(id, 5600);
        assert_eq!(s.job(id).attempts, 2);
        assert_eq!(s.job(id).goodput_s, 3600);
        assert_eq!(s.job(id).badput_s, 1800);
        s.check_invariants().unwrap();
    }

    #[test]
    fn idle_order_is_by_job_id() {
        let mut s = Schedd::new();
        let a = submit(&mut s, 60);
        let b = submit(&mut s, 60);
        let c = submit(&mut s, 60);
        assert_eq!(s.idle_jobs().collect::<Vec<_>>(), vec![a, b, c]);
        s.start(b, slot(1), 0);
        assert_eq!(s.idle_jobs().collect::<Vec<_>>(), vec![a, c]);
        // a requeued job resumes its JobId position, ahead of younger jobs
        s.interrupt(b, 10);
        assert_eq!(s.idle_jobs().collect::<Vec<_>>(), vec![a, b, c]);
    }

    #[test]
    fn invariants_catch_corruption() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 60);
        s.start(id, slot(1), 0);
        // simulate corruption: force state without updating queues
        s.jobs[0].state = JobState::Idle;
        assert!(s.check_invariants().is_err());
    }
}
