//! The schedd: job queue, submission, and goodput/badput accounting.
//!
//! With a [`CheckpointPolicy`] attached, interrupted jobs requeue at
//! their last checkpoint instead of zero (DESIGN.md §15): the wall
//! seconds covered by salvaged checkpoints count as goodput at
//! interrupt time, the un-checkpointed tail (plus any restore
//! overhead) is badput, and a completed job's goodput across all
//! attempts sums to exactly its ground-truth runtime.

use super::classad::{Ad, Expr};
use super::job::{Job, JobId, JobState};
use super::startd::SlotId;
use crate::config::CheckpointPolicy;
use crate::sim::SimTime;
use crate::util::fxhash::FxHashMap;
use std::collections::BTreeSet;

/// Aggregate queue statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScheddStats {
    pub submitted: u64,
    pub completed: u64,
    /// Attempts lost to preemption / connection loss (job went back idle).
    pub interrupted: u64,
    /// Productive wall seconds (completed attempts + salvaged
    /// checkpointed progress of interrupted ones).
    pub goodput_s: u64,
    /// Wasted wall seconds (lost tails, restore overheads, completion
    /// tick rounding).
    pub badput_s: u64,
    /// fp32 FLOPs of completed jobs.
    pub flops_done: f64,
    /// Wall seconds salvaged by checkpoint resume (subset of goodput_s).
    pub checkpoint_saved_s: u64,
    /// Job starts that resumed from a checkpoint.
    pub resumes: u64,
}

/// Goodput/badput wall seconds one `complete`/`interrupt` call settled;
/// the pool attributes these to the slot's provider.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkDelta {
    pub goodput_s: u64,
    pub badput_s: u64,
}

/// The job queue daemon.
#[derive(Debug, Default)]
pub struct Schedd {
    jobs: Vec<Job>,
    /// Idle jobs ordered by JobId (negotiation prefers older
    /// submissions; O(log n) insert/remove at campaign scale).
    idle: BTreeSet<JobId>,
    running: FxHashMap<JobId, SlotId>,
    /// Checkpoint/restart policy applied to every job in this queue.
    checkpoint: CheckpointPolicy,
    pub stats: ScheddStats,
}

impl Schedd {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the checkpoint/restart policy (campaign construction time;
    /// changing it mid-queue would misalign `completed_s` boundaries).
    pub fn set_checkpoint(&mut self, policy: CheckpointPolicy) {
        debug_assert!(
            self.jobs.is_empty(),
            "checkpoint policy must be set before jobs are submitted"
        );
        self.checkpoint = policy;
    }

    pub fn checkpoint_policy(&self) -> CheckpointPolicy {
        self.checkpoint
    }

    /// Wall seconds the next attempt of `id` will occupy a slot:
    /// restore overhead (for a resumed job) plus the not-yet-
    /// checkpointed remainder of the ground-truth runtime.
    pub fn attempt_runtime(&self, id: JobId) -> u64 {
        let job = &self.jobs[id.0 as usize];
        let overhead = if job.completed_s > 0 {
            self.checkpoint.resume_overhead_s()
        } else {
            0
        };
        overhead + (job.runtime_s - job.completed_s.min(job.runtime_s))
    }

    /// Submit a job; assigns its JobId.
    pub fn submit(
        &mut self,
        owner: &str,
        runtime_s: u64,
        flops: f64,
        bunches: u32,
        ad: Ad,
        requirements: Expr,
        now: SimTime,
    ) -> JobId {
        let id = JobId(self.jobs.len() as u64);
        let autocluster = super::job::autocluster_signature(&requirements, &ad);
        self.jobs.push(Job {
            id,
            owner: owner.to_string(),
            submitted_at: now,
            runtime_s,
            flops,
            bunches,
            state: JobState::Idle,
            attempts: 0,
            started_at: None,
            completed_at: None,
            goodput_s: 0,
            badput_s: 0,
            completed_s: 0,
            attempt_base_s: 0,
            attempt_overhead_s: 0,
            ad,
            requirements,
            autocluster,
        });
        self.idle.insert(id);
        self.stats.submitted += 1;
        id
    }

    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn idle_count(&self) -> usize {
        self.idle.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Idle job ids in JobId order (the negotiator's input).
    pub fn idle_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.idle.iter().copied()
    }

    /// The slot a running job occupies.
    pub fn slot_of(&self, id: JobId) -> Option<SlotId> {
        self.running.get(&id).copied()
    }

    /// Transition Idle -> Running on a successful match.  A job with
    /// checkpointed progress resumes from it (paying the restore
    /// overhead) instead of restarting from zero.
    pub fn start(&mut self, id: JobId, slot: SlotId, now: SimTime) {
        let overhead = if self.jobs[id.0 as usize].completed_s > 0 {
            self.checkpoint.resume_overhead_s()
        } else {
            0
        };
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Idle);
        job.state = JobState::Running;
        job.attempts += 1;
        job.started_at = Some(now);
        job.attempt_base_s = job.completed_s;
        job.attempt_overhead_s = overhead;
        if job.completed_s > 0 {
            self.stats.resumes += 1;
        }
        self.idle.remove(&id);
        self.running.insert(id, slot);
    }

    /// Transition Running -> Completed.  Goodput is the fresh work this
    /// attempt delivered (the job's total goodput across attempts sums
    /// to exactly `runtime_s`); restore overhead and completion tick
    /// rounding are badput.
    pub fn complete(&mut self, id: JobId, now: SimTime) -> WorkDelta {
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.state = JobState::Completed;
        job.completed_at = Some(now);
        let wall = now.saturating_sub(job.started_at.expect("running job"));
        let fresh =
            (job.runtime_s - job.attempt_base_s.min(job.runtime_s)).min(wall);
        let waste = wall - fresh;
        job.completed_s = job.runtime_s;
        job.goodput_s += fresh;
        job.badput_s += waste;
        self.running.remove(&id);
        self.stats.completed += 1;
        self.stats.goodput_s += fresh;
        self.stats.badput_s += waste;
        self.stats.flops_done += job.flops;
        WorkDelta { goodput_s: fresh, badput_s: waste }
    }

    /// Transition Running -> Idle (preemption, disconnect, outage).
    /// Progress covered by checkpoints taken during this attempt is
    /// salvaged as goodput and the job requeues there; the rest of the
    /// attempt's wall time is badput.  Under `CheckpointPolicy::None`
    /// nothing is salvaged — the paper's restart-from-scratch.
    pub fn interrupt(&mut self, id: JobId, now: SimTime) -> WorkDelta {
        let checkpoint = self.checkpoint;
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.state = JobState::Idle;
        let wall = now.saturating_sub(job.started_at.expect("running job"));
        // work actually performed this attempt (restore overhead is
        // not progress), capped at what the job had left
        let progress = wall
            .saturating_sub(job.attempt_overhead_s)
            .min(job.runtime_s - job.attempt_base_s.min(job.runtime_s));
        let reached = job.attempt_base_s + progress;
        // salvage never regresses: attempt_base_s is itself on the
        // checkpoint grid, so the floor can only move forward
        let salvaged = checkpoint.salvageable(reached).max(job.attempt_base_s);
        let saved = salvaged - job.attempt_base_s;
        let waste = wall - saved;
        job.completed_s = salvaged;
        job.goodput_s += saved;
        job.badput_s += waste;
        job.started_at = None;
        self.running.remove(&id);
        self.idle.insert(id);
        self.stats.interrupted += 1;
        self.stats.goodput_s += saved;
        self.stats.badput_s += waste;
        self.stats.checkpoint_saved_s += saved;
        WorkDelta { goodput_s: saved, badput_s: waste }
    }

    /// Sanity checks used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        for id in &self.idle {
            if self.jobs[id.0 as usize].state != JobState::Idle {
                return Err(format!("{id} in idle queue but not Idle"));
            }
        }
        for (id, _) in &self.running {
            if self.jobs[id.0 as usize].state != JobState::Running {
                return Err(format!("{id} in running map but not Running"));
            }
        }
        let counted = self
            .jobs
            .iter()
            .filter(|j| j.state == JobState::Idle)
            .count();
        if counted != self.idle.len() {
            return Err(format!(
                "idle queue {} != idle jobs {counted}",
                self.idle.len()
            ));
        }
        if self.stats.completed
            != self.jobs.iter().filter(|j| j.state == JobState::Completed).count()
                as u64
        {
            return Err("completed count mismatch".into());
        }
        for job in &self.jobs {
            if job.completed_s > job.runtime_s {
                return Err(format!(
                    "{} checkpointed past its runtime ({} > {})",
                    job.id, job.completed_s, job.runtime_s
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::InstanceId;
    use crate::condor::job::{gpu_job_ad, gpu_requirements};

    fn submit(s: &mut Schedd, runtime: u64) -> JobId {
        s.submit(
            "icecube",
            runtime,
            1e15,
            100,
            gpu_job_ad("icecube", 8192),
            gpu_requirements(),
            0,
        )
    }

    fn slot(n: u64) -> SlotId {
        SlotId::Cloud(InstanceId(n))
    }

    #[test]
    fn submit_enqueues_idle() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        assert_eq!(s.idle_count(), 1);
        assert_eq!(s.job(id).state, JobState::Idle);
        assert_eq!(s.stats.submitted, 1);
    }

    #[test]
    fn full_lifecycle_goodput() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        s.start(id, slot(1), 100);
        assert_eq!(s.running_count(), 1);
        assert_eq!(s.slot_of(id), Some(slot(1)));
        s.complete(id, 3700);
        assert_eq!(s.job(id).state, JobState::Completed);
        assert_eq!(s.job(id).goodput_s, 3600);
        assert_eq!(s.stats.goodput_s, 3600);
        assert_eq!(s.stats.flops_done, 1e15);
        s.check_invariants().unwrap();
    }

    #[test]
    fn interrupt_accrues_badput_and_requeues() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        s.start(id, slot(1), 0);
        s.interrupt(id, 1800); // preempted halfway
        assert_eq!(s.job(id).state, JobState::Idle);
        assert_eq!(s.job(id).badput_s, 1800);
        assert_eq!(s.idle_count(), 1);
        assert_eq!(s.stats.interrupted, 1);
        // second attempt succeeds
        s.start(id, slot(2), 2000);
        s.complete(id, 5600);
        assert_eq!(s.job(id).attempts, 2);
        assert_eq!(s.job(id).goodput_s, 3600);
        assert_eq!(s.job(id).badput_s, 1800);
        s.check_invariants().unwrap();
    }

    #[test]
    fn checkpointed_interrupt_salvages_progress() {
        let mut s = Schedd::new();
        s.set_checkpoint(CheckpointPolicy::Interval {
            every_s: 600,
            resume_overhead_s: 120,
        });
        let id = submit(&mut s, 3600);
        assert_eq!(s.attempt_runtime(id), 3600, "fresh job pays no overhead");
        s.start(id, slot(1), 0);
        // preempted at 1500: checkpoints at 600 and 1200 survive
        let d = s.interrupt(id, 1500);
        assert_eq!(d, WorkDelta { goodput_s: 1200, badput_s: 300 });
        let job = s.job(id);
        assert_eq!(job.completed_s, 1200);
        assert_eq!(job.goodput_s, 1200);
        assert_eq!(job.badput_s, 300);
        assert_eq!(s.stats.checkpoint_saved_s, 1200);
        // the next attempt resumes: overhead + the 2400 s remainder
        assert_eq!(s.attempt_runtime(id), 120 + 2400);
        s.start(id, slot(2), 2000);
        assert_eq!(s.stats.resumes, 1);
        let d = s.complete(id, 2000 + 2520);
        assert_eq!(d, WorkDelta { goodput_s: 2400, badput_s: 120 });
        // across attempts: goodput == ground-truth runtime exactly
        assert_eq!(s.job(id).goodput_s, 3600);
        assert_eq!(s.job(id).badput_s, 300 + 120);
        assert_eq!(s.job(id).completed_s, 3600);
        s.check_invariants().unwrap();
    }

    #[test]
    fn interrupt_during_restore_overhead_salvages_nothing() {
        let mut s = Schedd::new();
        s.set_checkpoint(CheckpointPolicy::Interval {
            every_s: 600,
            resume_overhead_s: 120,
        });
        let id = submit(&mut s, 3600);
        s.start(id, slot(1), 0);
        s.interrupt(id, 700); // salvages the 600 s checkpoint
        assert_eq!(s.job(id).completed_s, 600);
        s.start(id, slot(2), 1000);
        // killed 60 s in: still restoring, no fresh progress
        let d = s.interrupt(id, 1060);
        assert_eq!(d, WorkDelta { goodput_s: 0, badput_s: 60 });
        assert_eq!(s.job(id).completed_s, 600, "checkpoint never regresses");
        s.check_invariants().unwrap();
    }

    #[test]
    fn no_checkpoint_policy_restarts_from_scratch() {
        // the paper baseline: an interrupt wastes the whole attempt
        let mut s = Schedd::new();
        let id = submit(&mut s, 3600);
        s.start(id, slot(1), 0);
        let d = s.interrupt(id, 3599);
        assert_eq!(d, WorkDelta { goodput_s: 0, badput_s: 3599 });
        assert_eq!(s.job(id).completed_s, 0);
        assert_eq!(s.attempt_runtime(id), 3600, "restart from zero");
        assert_eq!(s.stats.resumes, 0);
        assert_eq!(s.stats.checkpoint_saved_s, 0);
    }

    #[test]
    fn completion_tick_rounding_lands_in_badput() {
        // the pool completes at the first tick >= finish; the residue
        // must not inflate goodput past the ground-truth runtime
        let mut s = Schedd::new();
        let id = submit(&mut s, 3_590);
        s.start(id, slot(1), 0);
        let d = s.complete(id, 3_600);
        assert_eq!(d, WorkDelta { goodput_s: 3_590, badput_s: 10 });
        assert_eq!(s.job(id).goodput_s, 3_590);
    }

    #[test]
    fn idle_order_is_by_job_id() {
        let mut s = Schedd::new();
        let a = submit(&mut s, 60);
        let b = submit(&mut s, 60);
        let c = submit(&mut s, 60);
        assert_eq!(s.idle_jobs().collect::<Vec<_>>(), vec![a, b, c]);
        s.start(b, slot(1), 0);
        assert_eq!(s.idle_jobs().collect::<Vec<_>>(), vec![a, c]);
        // a requeued job resumes its JobId position, ahead of younger jobs
        s.interrupt(b, 10);
        assert_eq!(s.idle_jobs().collect::<Vec<_>>(), vec![a, b, c]);
    }

    #[test]
    fn invariants_catch_corruption() {
        let mut s = Schedd::new();
        let id = submit(&mut s, 60);
        s.start(id, slot(1), 0);
        // simulate corruption: force state without updating queues
        s.jobs[0].state = JobState::Idle;
        assert!(s.check_invariants().is_err());
    }
}
