//! The negotiator: bilateral matchmaking between idle jobs and slots.
//!
//! Implements HTCondor-style autoclustering: idle jobs with identical
//! matchmaking inputs (Requirements + job ad) form one autocluster, and
//! candidate slots are evaluated once per cluster instead of once per
//! job.  With IceCube's homogeneous GPU jobs this turns each negotiation
//! cycle from O(jobs × slots) ClassAd evaluations into O(slots).

use super::job::JobId;
use super::schedd::Schedd;
use super::startd::{SlotId, Startd};
use crate::util::fxhash::FxHashMap;

/// Default negotiation cycle period (HTCondor NEGOTIATOR_INTERVAL: 300 s).
pub const DEFAULT_CYCLE_S: u64 = 300;

/// One matchmaking cycle's outcome.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CycleResult {
    pub matches: Vec<(JobId, SlotId)>,
    pub idle_considered: usize,
    pub slots_considered: usize,
    pub autoclusters: usize,
    /// ClassAd (requirements, start) evaluations performed.
    pub evaluations: u64,
}

/// Run one negotiation cycle; returns matches without applying them.
///
/// `max_matches` caps how many claims a single cycle may hand out (real
/// negotiators bound cycle length the same way).
pub fn negotiate(
    schedd: &Schedd,
    startds: &FxHashMap<SlotId, Startd>,
    slots_in_collector: impl Iterator<Item = SlotId>,
    max_matches: usize,
) -> CycleResult {
    let mut result = CycleResult::default();

    // candidate slots: advertised, connected, unclaimed
    let mut candidates: Vec<SlotId> = slots_in_collector
        .filter(|s| startds.get(s).map(|d| d.is_unclaimed()).unwrap_or(false))
        .collect();
    candidates.sort_unstable(); // determinism regardless of map order
    result.slots_considered = candidates.len();

    // group idle jobs into autoclusters, preserving queue order
    let mut clusters: Vec<(&str, Vec<JobId>)> = Vec::new();
    let mut cluster_index: FxHashMap<&str, usize> = FxHashMap::default();
    for id in schedd.idle_jobs() {
        let key = schedd.job(id).autocluster_key();
        match cluster_index.get(key) {
            Some(&i) => clusters[i].1.push(id),
            None => {
                cluster_index.insert(key, clusters.len());
                clusters.push((key, vec![id]));
            }
        }
        result.idle_considered += 1;
    }
    result.autoclusters = clusters.len();

    let mut claimed: Vec<bool> = vec![false; candidates.len()];
    for (_, jobs) in &clusters {
        let representative = schedd.job(jobs[0]);
        let mut job_iter = jobs.iter();
        let mut current = job_iter.next();
        for (slot_idx, slot) in candidates.iter().enumerate() {
            if current.is_none() || result.matches.len() >= max_matches {
                break;
            }
            if claimed[slot_idx] {
                continue;
            }
            let startd = &startds[slot];
            // bilateral match, evaluated once per (cluster, slot)
            result.evaluations += 2;
            let job_ok = representative
                .requirements
                .matches(&representative.ad, Some(&startd.ad));
            let machine_ok = startd
                .start_expr
                .matches(&startd.ad, Some(&representative.ad));
            if job_ok && machine_ok {
                let job_id = *current.unwrap();
                result.matches.push((job_id, *slot));
                claimed[slot_idx] = true;
                current = job_iter.next();
            }
        }
        if result.matches.len() >= max_matches {
            break;
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::{InstanceId, Provider};
    use crate::condor::job::{gpu_job_ad, gpu_requirements};
    use crate::net::NatProfile;

    fn make_startd(n: u64) -> Startd {
        Startd::new(
            SlotId::Cloud(InstanceId(n)),
            "cloud",
            Some(Provider::Azure),
            "azure/eastus",
            NatProfile::permissive("test"),
            60,
            0,
        )
    }

    fn pool(n: u64) -> FxHashMap<SlotId, Startd> {
        (0..n).map(|i| (SlotId::Cloud(InstanceId(i)), make_startd(i))).collect()
    }

    fn schedd_with_jobs(n: u64) -> Schedd {
        let mut s = Schedd::new();
        for _ in 0..n {
            s.submit(
                "icecube",
                3600,
                1e15,
                100,
                gpu_job_ad("icecube", 8192),
                gpu_requirements(),
                0,
            );
        }
        s
    }

    #[test]
    fn matches_jobs_to_free_slots() {
        let schedd = schedd_with_jobs(5);
        let startds = pool(3);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        assert_eq!(r.matches.len(), 3); // slot-limited
        assert_eq!(r.autoclusters, 1);
        // distinct slots, distinct jobs
        let mut slots: Vec<_> = r.matches.iter().map(|(_, s)| *s).collect();
        slots.sort();
        slots.dedup();
        assert_eq!(slots.len(), 3);
    }

    #[test]
    fn job_limited_when_more_slots() {
        let schedd = schedd_with_jobs(2);
        let startds = pool(10);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        assert_eq!(r.matches.len(), 2);
    }

    #[test]
    fn autoclustering_evaluates_once_per_slot() {
        let schedd = schedd_with_jobs(100);
        let startds = pool(10);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        // one cluster * 10 slots * 2 evaluations
        assert_eq!(r.evaluations, 20);
        assert_eq!(r.matches.len(), 10);
    }

    #[test]
    fn claimed_slots_are_skipped() {
        let schedd = schedd_with_jobs(5);
        let mut startds = pool(3);
        startds
            .get_mut(&SlotId::Cloud(InstanceId(1)))
            .unwrap()
            .claim_for(JobId(999), 0, 60);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        assert_eq!(r.matches.len(), 2);
        assert!(r
            .matches
            .iter()
            .all(|(_, s)| *s != SlotId::Cloud(InstanceId(1))));
    }

    #[test]
    fn disconnected_slots_are_skipped() {
        let schedd = schedd_with_jobs(5);
        let mut startds = pool(3);
        startds
            .get_mut(&SlotId::Cloud(InstanceId(0)))
            .unwrap()
            .conn
            .sever();
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        assert_eq!(r.matches.len(), 2);
    }

    #[test]
    fn slots_absent_from_collector_not_matched() {
        let schedd = schedd_with_jobs(5);
        let startds = pool(5);
        // collector only knows 2 of the 5
        let known = vec![
            SlotId::Cloud(InstanceId(0)),
            SlotId::Cloud(InstanceId(3)),
        ];
        let r = negotiate(&schedd, &startds, known.into_iter(), 1000);
        assert_eq!(r.matches.len(), 2);
    }

    #[test]
    fn non_icecube_jobs_rejected_by_start() {
        let mut schedd = Schedd::new();
        schedd.submit(
            "cms",
            3600,
            1e15,
            100,
            gpu_job_ad("cms", 8192),
            gpu_requirements(),
            0,
        );
        let startds = pool(3);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        assert!(r.matches.is_empty());
    }

    #[test]
    fn max_matches_cap_respected() {
        let schedd = schedd_with_jobs(100);
        let startds = pool(100);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 7);
        assert_eq!(r.matches.len(), 7);
    }

    #[test]
    fn heterogeneous_jobs_form_multiple_autoclusters() {
        let mut schedd = Schedd::new();
        for mem in [8192i64, 8192, 4096] {
            schedd.submit(
                "icecube",
                3600,
                1e15,
                100,
                gpu_job_ad("icecube", mem),
                gpu_requirements(),
                0,
            );
        }
        let startds = pool(3);
        let r = negotiate(&schedd, &startds, startds.keys().copied(), 1000);
        assert_eq!(r.autoclusters, 2);
        assert_eq!(r.matches.len(), 3);
    }
}
