//! The collector: the central-manager daemon holding machine ads.
//!
//! Startds advertise themselves with periodic updates; ads that miss
//! updates for `classad_lifetime_s` expire (exactly how a real pool
//! "loses" workers during a network outage — nothing tears them down,
//! the collector just stops hearing from them).

use super::classad::Ad;
use super::startd::SlotId;
use crate::sim::SimTime;
use crate::util::fxhash::FxHashMap;

/// Default HTCondor CLASSAD_LIFETIME (15 minutes).
pub const DEFAULT_CLASSAD_LIFETIME_S: u64 = 900;

#[derive(Debug, Clone)]
struct Entry {
    ad: Ad,
    last_heard: SimTime,
}

/// Machine-ad registry.
#[derive(Debug, Default)]
pub struct Collector {
    ads: FxHashMap<SlotId, Entry>,
    pub classad_lifetime_s: u64,
}

impl Collector {
    pub fn new() -> Self {
        Collector {
            ads: FxHashMap::default(),
            classad_lifetime_s: DEFAULT_CLASSAD_LIFETIME_S,
        }
    }

    /// Insert or refresh a machine ad.
    pub fn update(&mut self, slot: SlotId, ad: Ad, now: SimTime) {
        self.ads.insert(slot, Entry { ad, last_heard: now });
    }

    /// Refresh the heartbeat of an existing ad (keepalive without a
    /// content change).
    pub fn heartbeat(&mut self, slot: SlotId, now: SimTime) {
        if let Some(e) = self.ads.get_mut(&slot) {
            e.last_heard = now;
        }
    }

    /// Explicitly remove an ad (graceful shutdown / invalidation).
    pub fn invalidate(&mut self, slot: SlotId) {
        self.ads.remove(&slot);
    }

    /// Drop ads that have not been heard from within the lifetime.
    /// Returns the expired slots.
    pub fn expire(&mut self, now: SimTime) -> Vec<SlotId> {
        let lifetime = self.classad_lifetime_s;
        let expired: Vec<SlotId> = self
            .ads
            .iter()
            .filter(|(_, e)| now.saturating_sub(e.last_heard) > lifetime)
            .map(|(s, _)| *s)
            .collect();
        for s in &expired {
            self.ads.remove(s);
        }
        expired
    }

    pub fn contains(&self, slot: SlotId) -> bool {
        self.ads.contains_key(&slot)
    }

    pub fn get(&self, slot: SlotId) -> Option<&Ad> {
        self.ads.get(&slot).map(|e| &e.ad)
    }

    pub fn len(&self) -> usize {
        self.ads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ads.is_empty()
    }

    pub fn slots(&self) -> impl Iterator<Item = SlotId> + '_ {
        self.ads.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::InstanceId;

    fn slot(n: u64) -> SlotId {
        SlotId::Cloud(InstanceId(n))
    }

    #[test]
    fn update_and_query() {
        let mut c = Collector::new();
        c.update(slot(1), Ad::new(), 100);
        assert!(c.contains(slot(1)));
        assert_eq!(c.len(), 1);
        assert!(c.get(slot(1)).is_some());
        assert!(c.get(slot(2)).is_none());
    }

    #[test]
    fn expiry_after_lifetime() {
        let mut c = Collector::new();
        c.update(slot(1), Ad::new(), 0);
        c.update(slot(2), Ad::new(), 800);
        let expired = c.expire(901); // slot1 is 901s stale (> 900)
        assert_eq!(expired, vec![slot(1)]);
        assert!(!c.contains(slot(1)));
        assert!(c.contains(slot(2)));
    }

    #[test]
    fn heartbeat_prevents_expiry() {
        let mut c = Collector::new();
        c.update(slot(1), Ad::new(), 0);
        c.heartbeat(slot(1), 600);
        assert!(c.expire(1200).is_empty());
        assert!(c.contains(slot(1)));
    }

    #[test]
    fn heartbeat_on_unknown_slot_is_noop() {
        let mut c = Collector::new();
        c.heartbeat(slot(9), 10);
        assert!(c.is_empty());
    }

    #[test]
    fn invalidate_removes() {
        let mut c = Collector::new();
        c.update(slot(1), Ad::new(), 0);
        c.invalidate(slot(1));
        assert!(c.is_empty());
    }

    #[test]
    fn outage_expires_whole_pool() {
        // the Fig-1 collapse: no updates during a 2 h outage -> empty pool
        let mut c = Collector::new();
        for i in 0..100 {
            c.update(slot(i), Ad::new(), 1000);
        }
        let expired = c.expire(1000 + 7200);
        assert_eq!(expired.len(), 100);
        assert!(c.is_empty());
    }
}
