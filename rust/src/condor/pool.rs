//! The HTCondor pool: collector + negotiator + schedd + startds, glued.
//!
//! [`CondorPool::tick`] advances the whole workload-management plane:
//! keepalives (through each region's NAT), collector ad expiry, job
//! completions, reconnects, and periodic negotiation cycles.  A CE-host
//! network outage is modeled by severing every management connection and
//! refusing reconnects until the outage clears — which reproduces the
//! paper's "total collapse of the backend workload management system".

use super::collector::Collector;
use super::negotiator::{negotiate, DEFAULT_CYCLE_S};
use super::schedd::{Schedd, WorkDelta};
use super::startd::{Claim, SlotId, Startd, RECONNECT_DELAY_S};
use crate::cloud::Provider;
use crate::net::SendOutcome;
use crate::sim::{EventQueue, SimTime, Ticker};
use crate::util::fxhash::FxHashMap;

/// Events the pool reports upward (monitoring / real-compute sampling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolEvent {
    JobStarted(SlotId),
    JobCompleted(SlotId),
    /// A running job lost its slot (NAT drop, preemption, outage).
    JobInterrupted(SlotId, InterruptCause),
    /// A startd's ad expired from the collector (stale heartbeat).
    SlotExpired(SlotId),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InterruptCause {
    NatDrop,
    WorkerLost,
    Outage,
}

/// Cumulative pool statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    pub nat_drops: u64,
    pub negotiation_cycles: u64,
    pub matches: u64,
    pub classad_evaluations: u64,
    /// Goodput wall seconds settled on cloud slots, per provider in
    /// `[aws, gcp, azure]` order (on-prem slots are excluded — they
    /// carry no provider and no bill).
    pub goodput_by_provider: [u64; 3],
    /// Badput wall seconds settled on cloud slots, per provider.
    pub badput_by_provider: [u64; 3],
}

/// The assembled workload-management plane.
pub struct CondorPool {
    pub collector: Collector,
    pub schedd: Schedd,
    startds: FxHashMap<SlotId, Startd>,
    /// Scratch buffer reused by the keepalive sweep (avoids a per-tick
    /// allocation of every slot id).
    scratch: Vec<SlotId>,
    negotiation: Ticker,
    /// Max matches a single negotiation cycle may hand out.
    pub max_matches_per_cycle: usize,
    outage: bool,
    /// Incremental busy-slot counters (claim/release sites keep these in
    /// sync; scanning every startd per tick showed up in the profile).
    busy_cloud: usize,
    busy_onprem: usize,
    /// Busy cloud slots per provider (`[aws, gcp, azure]`), maintained
    /// at the same claim/release sites — the billing meter samples this
    /// every tick to split instance-hours into busy vs idle.
    busy_by_provider: [usize; 3],
    pub stats: PoolStats,
    /// Queue of upcoming job-completion times (avoids scanning all slots
    /// every tick).
    completions: EventQueue<SlotId>,
}

impl CondorPool {
    pub fn new() -> Self {
        CondorPool {
            collector: Collector::new(),
            schedd: Schedd::new(),
            startds: FxHashMap::default(),
            scratch: Vec::new(),
            negotiation: Ticker::new(DEFAULT_CYCLE_S, 0),
            max_matches_per_cycle: 5000,
            outage: false,
            busy_cloud: 0,
            busy_onprem: 0,
            busy_by_provider: [0; 3],
            stats: PoolStats::default(),
            completions: EventQueue::new(),
        }
    }

    pub fn with_negotiation_period(mut self, period: SimTime) -> Self {
        self.negotiation = Ticker::new(period, 0);
        self
    }

    /// Attach the job checkpoint/restart policy (construction time).
    pub fn with_checkpoint(
        mut self,
        policy: crate::config::CheckpointPolicy,
    ) -> Self {
        self.schedd.set_checkpoint(policy);
        self
    }

    // ---- worker membership -------------------------------------------------

    /// A worker came up: register its startd and advertise it.
    pub fn add_startd(&mut self, startd: Startd, now: SimTime) {
        if !self.outage {
            self.collector.update(startd.slot, startd.ad.clone(), now);
        }
        self.startds.insert(startd.slot, startd);
    }

    /// A worker vanished (spot preemption / deprovision). Any running job
    /// is interrupted and requeued.
    pub fn remove_startd(
        &mut self,
        slot: SlotId,
        now: SimTime,
        events: &mut Vec<PoolEvent>,
    ) {
        if let Some(mut startd) = self.startds.remove(&slot) {
            if let Some(claim) = startd.release() {
                Self::count_claim(
                    &mut self.busy_cloud,
                    &mut self.busy_onprem,
                    &mut self.busy_by_provider,
                    &startd,
                    -1,
                );
                let delta = self.schedd.interrupt(claim.job, now);
                Self::credit_work(&mut self.stats, startd.provider, delta);
                events.push(PoolEvent::JobInterrupted(
                    slot,
                    InterruptCause::WorkerLost,
                ));
            }
            self.collector.invalidate(slot);
        }
    }

    pub fn startd(&self, slot: SlotId) -> Option<&Startd> {
        self.startds.get(&slot)
    }

    pub fn num_startds(&self) -> usize {
        self.startds.len()
    }

    /// Slots currently executing a job, with pool tags (Fig 2 accounting).
    pub fn running_slots(&self) -> impl Iterator<Item = (&Startd, Claim)> + '_ {
        self.startds
            .values()
            .filter_map(|d| d.claim.map(|c| (d, c)))
    }

    pub fn running_by_tag(&self, tag: &str) -> usize {
        self.running_slots().filter(|(d, _)| d.pool_tag == tag).count()
    }

    /// O(1) (cloud, onprem) busy-slot counts, maintained incrementally at
    /// every claim/release site (scanning every startd per tick showed up
    /// in the campaign profile).
    pub fn running_cloud_onprem(&self) -> (usize, usize) {
        (self.busy_cloud, self.busy_onprem)
    }

    /// O(1) busy cloud slots per provider (`[aws, gcp, azure]`).
    pub fn busy_by_provider(&self) -> [usize; 3] {
        self.busy_by_provider
    }

    /// Wall seconds of claims still running at `now`, per provider —
    /// work neither settled as goodput nor badput yet.  Campaign-end
    /// accounting needs this for the conservation identity
    /// `busy == goodput + badput + in-flight` (tested in
    /// `rust/tests/integration_campaign.rs`).
    pub fn inflight_by_provider(&self, now: SimTime) -> [u64; 3] {
        let mut out = [0u64; 3];
        for d in self.startds.values() {
            if let (Some(p), Some(claim)) = (d.provider, d.claim) {
                out[p.index()] += now.saturating_sub(claim.started_at);
            }
        }
        out
    }

    fn count_claim(
        busy_cloud: &mut usize,
        busy_onprem: &mut usize,
        busy_by_provider: &mut [usize; 3],
        startd: &Startd,
        delta: isize,
    ) {
        let c = match startd.pool_tag {
            "cloud" => busy_cloud,
            "onprem" => busy_onprem,
            _ => return,
        };
        *c = c.checked_add_signed(delta).expect("busy counter underflow");
        if let Some(p) = startd.provider {
            let c = &mut busy_by_provider[p.index()];
            *c = c
                .checked_add_signed(delta)
                .expect("provider busy counter underflow");
        }
    }

    /// Attribute settled goodput/badput wall seconds to the slot's
    /// provider (on-prem slots carry no provider and no bill).
    fn credit_work(
        stats: &mut PoolStats,
        provider: Option<Provider>,
        delta: WorkDelta,
    ) {
        if let Some(p) = provider {
            stats.goodput_by_provider[p.index()] += delta.goodput_s;
            stats.badput_by_provider[p.index()] += delta.badput_s;
        }
    }

    pub fn unclaimed_count(&self) -> usize {
        self.startds.values().filter(|d| d.is_unclaimed()).count()
    }

    // ---- outage control ------------------------------------------------------

    /// Begin a CE-host network outage: every management connection dies
    /// and running jobs are lost (the backend WMS collapses).
    pub fn begin_outage(&mut self, now: SimTime, events: &mut Vec<PoolEvent>) {
        self.outage = true;
        let slots: Vec<SlotId> = self.startds.keys().copied().collect();
        for slot in slots {
            let startd = self.startds.get_mut(&slot).expect(
                "pool invariant violated: slot snapshotted from startds \
                 keys disappeared during the outage sweep (nothing may \
                 deregister startds while begin_outage runs)",
            );
            startd.conn.sever();
            startd.reconnect_at = Some(now + RECONNECT_DELAY_S);
            if let Some(claim) = startd.release() {
                Self::count_claim(
                    &mut self.busy_cloud,
                    &mut self.busy_onprem,
                    &mut self.busy_by_provider,
                    startd,
                    -1,
                );
                let provider = startd.provider;
                let delta = self.schedd.interrupt(claim.job, now);
                Self::credit_work(&mut self.stats, provider, delta);
                events.push(PoolEvent::JobInterrupted(slot, InterruptCause::Outage));
            }
        }
    }

    /// Outage resolved; workers may reconnect on their next retry.
    pub fn end_outage(&mut self) {
        self.outage = false;
    }

    pub fn in_outage(&self) -> bool {
        self.outage
    }

    // ---- time advance ----------------------------------------------------------

    /// Advance the management plane by one tick.
    pub fn tick(&mut self, now: SimTime, events: &mut Vec<PoolEvent>) {
        self.run_keepalives(now, events);
        self.run_completions(now, events);
        self.expire_ads(now, events);
        if self.negotiation.due(now) {
            self.run_negotiation(now, events);
        }
    }

    fn run_keepalives(&mut self, now: SimTime, events: &mut Vec<PoolEvent>) {
        let mut slots = std::mem::take(&mut self.scratch);
        slots.clear();
        slots.extend(self.startds.keys().copied());
        for &slot in &slots {
            let startd = self.startds.get_mut(&slot).expect(
                "pool invariant violated: slot snapshotted from startds \
                 keys disappeared mid-tick (keepalives never deregister \
                 workers; only provisioning teardown may)",
            );

            // reconnect attempts
            if let Some(at) = startd.reconnect_at {
                if now >= at {
                    if self.outage {
                        // retry again later; the path is still down
                        startd.reconnect_at = Some(now + RECONNECT_DELAY_S * 4);
                    } else {
                        startd.conn.reconnect(now);
                        startd.reconnect_at = None;
                        startd.next_keepalive = now + startd.keepalive_s;
                        self.collector.update(slot, startd.ad.clone(), now);
                    }
                }
                continue;
            }

            if !startd.conn.alive || now < startd.next_keepalive {
                continue;
            }

            // during an outage sends cannot reach the central manager
            if self.outage {
                startd.conn.sever();
                startd.reconnect_at = Some(now + RECONNECT_DELAY_S);
                if let Some(claim) = startd.release() {
                    Self::count_claim(
                        &mut self.busy_cloud,
                        &mut self.busy_onprem,
                        &mut self.busy_by_provider,
                        startd,
                        -1,
                    );
                    let provider = startd.provider;
                    let delta = self.schedd.interrupt(claim.job, now);
                    Self::credit_work(&mut self.stats, provider, delta);
                    events.push(PoolEvent::JobInterrupted(
                        slot,
                        InterruptCause::Outage,
                    ));
                }
                continue;
            }

            match startd.conn.try_send(now) {
                SendOutcome::Delivered => {
                    self.collector.heartbeat(slot, now);
                    startd.next_keepalive = now + startd.keepalive_s;
                }
                SendOutcome::DroppedByNat => {
                    // the §IV incident: claim connection silently died
                    self.stats.nat_drops += 1;
                    startd.reconnect_at = Some(now + RECONNECT_DELAY_S);
                    if let Some(claim) = startd.release() {
                        Self::count_claim(
                            &mut self.busy_cloud,
                            &mut self.busy_onprem,
                            &mut self.busy_by_provider,
                            startd,
                            -1,
                        );
                        let provider = startd.provider;
                        let delta = self.schedd.interrupt(claim.job, now);
                        Self::credit_work(&mut self.stats, provider, delta);
                        events.push(PoolEvent::JobInterrupted(
                            slot,
                            InterruptCause::NatDrop,
                        ));
                    }
                }
                SendOutcome::NotConnected => {
                    startd.reconnect_at = Some(now + RECONNECT_DELAY_S);
                }
            }
        }
        self.scratch = slots;
    }

    fn run_completions(&mut self, now: SimTime, events: &mut Vec<PoolEvent>) {
        while let Some(t) = self.completions.peek_time() {
            if t > now {
                break;
            }
            let (_, slot) = self.completions.pop().unwrap();
            let Some(startd) = self.startds.get_mut(&slot) else {
                continue; // worker already gone; schedd was updated then
            };
            let Some(claim) = startd.claim else {
                continue; // claim already released (interrupt); stale entry
            };
            if claim.finish_at > now {
                continue; // stale entry from an earlier claim
            }
            startd.release();
            Self::count_claim(
                &mut self.busy_cloud,
                &mut self.busy_onprem,
                &mut self.busy_by_provider,
                startd,
                -1,
            );
            let provider = startd.provider;
            if startd.conn.alive {
                let delta = self.schedd.complete(claim.job, now);
                Self::credit_work(&mut self.stats, provider, delta);
                events.push(PoolEvent::JobCompleted(slot));
            } else {
                // results can't be delivered; attempt is lost
                let delta = self.schedd.interrupt(claim.job, now);
                Self::credit_work(&mut self.stats, provider, delta);
                events.push(PoolEvent::JobInterrupted(
                    slot,
                    InterruptCause::WorkerLost,
                ));
            }
        }
    }

    fn expire_ads(&mut self, now: SimTime, events: &mut Vec<PoolEvent>) {
        for slot in self.collector.expire(now) {
            events.push(PoolEvent::SlotExpired(slot));
        }
    }

    fn run_negotiation(&mut self, now: SimTime, events: &mut Vec<PoolEvent>) {
        self.stats.negotiation_cycles += 1;
        if self.outage {
            return; // negotiator can't reach anything either
        }
        let result = negotiate(
            &self.schedd,
            &self.startds,
            self.collector.slots(),
            self.max_matches_per_cycle,
        );
        self.stats.classad_evaluations += result.evaluations;
        for (job, slot) in result.matches {
            // checkpoint-aware: a resumed job occupies the slot for the
            // restore overhead plus its remaining work, not the full
            // ground-truth runtime (schedd.stats.resumes counts the
            // resumed starts)
            let runtime = self.schedd.attempt_runtime(job);
            self.schedd.start(job, slot, now);
            let startd = self.startds.get_mut(&slot).expect(
                "pool invariant violated: negotiator matched a job to a \
                 slot with no startd entry (matchmaking must only see \
                 ads of registered workers)",
            );
            startd.claim_for(job, now, runtime);
            Self::count_claim(
                &mut self.busy_cloud,
                &mut self.busy_onprem,
                &mut self.busy_by_provider,
                startd,
                1,
            );
            self.completions.push_at(now + runtime, slot);
            self.stats.matches += 1;
            events.push(PoolEvent::JobStarted(slot));
        }
    }

    /// Pool-wide invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.schedd.check_invariants()?;
        // incremental busy counters must agree with a full scan
        let mut cloud = 0usize;
        let mut onprem = 0usize;
        let mut by_provider = [0usize; 3];
        for d in self.startds.values() {
            if d.claim.is_some() {
                match d.pool_tag {
                    "cloud" => cloud += 1,
                    "onprem" => onprem += 1,
                    _ => {}
                }
                if let Some(p) = d.provider {
                    by_provider[p.index()] += 1;
                }
            }
        }
        if (cloud, onprem) != (self.busy_cloud, self.busy_onprem) {
            return Err(format!(
                "busy counters drifted: scan ({cloud},{onprem}) !=                  counters ({},{})",
                self.busy_cloud, self.busy_onprem
            ));
        }
        if by_provider != self.busy_by_provider {
            return Err(format!(
                "per-provider busy counters drifted: scan {by_provider:?} \
                 != counters {:?}",
                self.busy_by_provider
            ));
        }
        for (slot, startd) in &self.startds {
            if *slot != startd.slot {
                return Err(format!("slot key mismatch for {slot}"));
            }
            if let Some(claim) = startd.claim {
                match self.schedd.slot_of(claim.job) {
                    Some(s) if s == *slot => {}
                    other => {
                        return Err(format!(
                            "claim on {slot} not reflected in schedd ({other:?})"
                        ))
                    }
                }
            }
        }
        // every running job's slot must hold the matching claim
        for job in self.schedd.jobs() {
            if job.state == super::job::JobState::Running {
                let slot = self
                    .schedd
                    .slot_of(job.id)
                    .ok_or_else(|| format!("running {} has no slot", job.id))?;
                let startd = self
                    .startds
                    .get(&slot)
                    .ok_or_else(|| format!("running {} on missing {slot}", job.id))?;
                match startd.claim {
                    Some(c) if c.job == job.id => {}
                    _ => {
                        return Err(format!(
                            "running {} not claimed on {slot}",
                            job.id
                        ))
                    }
                }
            }
        }
        Ok(())
    }
}

impl Default for CondorPool {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::{InstanceId, Provider};
    use crate::condor::job::{gpu_job_ad, gpu_requirements};
    use crate::net::NatProfile;
    use crate::sim::MINUTE;

    fn add_worker(pool: &mut CondorPool, n: u64, keepalive: u64, nat: NatProfile, now: SimTime) {
        let slot = SlotId::Cloud(InstanceId(n));
        let startd = Startd::new(
            slot,
            "cloud",
            Some(Provider::Azure),
            "azure/eastus",
            nat,
            keepalive,
            now,
        );
        pool.add_startd(startd, now);
    }

    fn submit_jobs(pool: &mut CondorPool, n: u64, runtime: u64) {
        for _ in 0..n {
            pool.schedd.submit(
                "icecube", runtime, 1e15, 100,
                gpu_job_ad("icecube", 8192), gpu_requirements(), 0,
            );
        }
    }

    fn run(pool: &mut CondorPool, from: SimTime, ticks: u64) -> Vec<PoolEvent> {
        let mut events = Vec::new();
        for i in 0..ticks {
            pool.tick(from + i * MINUTE, &mut events);
        }
        events
    }

    #[test]
    fn jobs_match_and_complete() {
        let mut pool = CondorPool::new();
        for i in 0..4 {
            add_worker(&mut pool, i, 60, NatProfile::permissive("x"), 0);
        }
        submit_jobs(&mut pool, 10, 30 * MINUTE);
        let events = run(&mut pool, 0, 40);
        let started = events.iter().filter(|e| matches!(e, PoolEvent::JobStarted(_))).count();
        let completed = events.iter().filter(|e| matches!(e, PoolEvent::JobCompleted(_))).count();
        assert_eq!(completed, 4, "first wave completes inside 40 min");
        assert!(started >= 8, "second wave starts, started={started}");
        assert_eq!(pool.schedd.stats.completed, 4);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn all_jobs_eventually_drain() {
        let mut pool = CondorPool::new();
        for i in 0..8 {
            add_worker(&mut pool, i, 60, NatProfile::permissive("x"), 0);
        }
        submit_jobs(&mut pool, 24, 20 * MINUTE);
        run(&mut pool, 0, 6 * 60);
        assert_eq!(pool.schedd.stats.completed, 24);
        assert_eq!(pool.schedd.idle_count(), 0);
        assert_eq!(pool.schedd.stats.badput_s, 0);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn nat_drop_storm_with_default_keepalive() {
        // §IV incident: OSG default 300 s keepalive on Azure default NAT
        let mut pool = CondorPool::new();
        for i in 0..4 {
            add_worker(&mut pool, i, 300, NatProfile::azure_default(), 0);
        }
        submit_jobs(&mut pool, 8, 2 * 3600);
        let events = run(&mut pool, 0, 120);
        let nat_drops = events
            .iter()
            .filter(|e| {
                matches!(e, PoolEvent::JobInterrupted(_, InterruptCause::NatDrop))
            })
            .count();
        assert!(nat_drops >= 4, "constant preemption expected, got {nat_drops}");
        assert_eq!(pool.schedd.stats.completed, 0, "nothing can finish");
        assert!(pool.schedd.stats.badput_s > 0);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn tuned_keepalive_fixes_azure() {
        let mut pool = CondorPool::new();
        for i in 0..4 {
            add_worker(&mut pool, i, 60, NatProfile::azure_default(), 0);
        }
        submit_jobs(&mut pool, 4, 2 * 3600);
        run(&mut pool, 0, 3 * 60);
        assert_eq!(pool.stats.nat_drops, 0);
        assert_eq!(pool.schedd.stats.completed, 4);
        assert_eq!(pool.schedd.stats.badput_s, 0);
    }

    #[test]
    fn worker_loss_requeues_job() {
        let mut pool = CondorPool::new();
        add_worker(&mut pool, 0, 60, NatProfile::permissive("x"), 0);
        submit_jobs(&mut pool, 1, 3600);
        run(&mut pool, 0, 10);
        assert_eq!(pool.schedd.running_count(), 1);
        let mut events = Vec::new();
        pool.remove_startd(SlotId::Cloud(InstanceId(0)), 11 * MINUTE, &mut events);
        assert!(matches!(
            events[0],
            PoolEvent::JobInterrupted(_, InterruptCause::WorkerLost)
        ));
        assert_eq!(pool.schedd.idle_count(), 1);
        assert_eq!(pool.num_startds(), 0);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn outage_collapses_and_recovers() {
        let mut pool = CondorPool::new();
        for i in 0..6 {
            add_worker(&mut pool, i, 60, NatProfile::permissive("x"), 0);
        }
        submit_jobs(&mut pool, 6, 4 * 3600);
        run(&mut pool, 0, 10);
        assert_eq!(pool.schedd.running_count(), 6);

        let mut events = Vec::new();
        pool.begin_outage(10 * MINUTE, &mut events);
        assert_eq!(
            events
                .iter()
                .filter(|e| matches!(
                    e,
                    PoolEvent::JobInterrupted(_, InterruptCause::Outage)
                ))
                .count(),
            6
        );
        assert_eq!(pool.schedd.running_count(), 0);

        // during the outage nothing matches and ads expire
        run(&mut pool, 11 * MINUTE, 30);
        assert_eq!(pool.schedd.running_count(), 0);
        assert_eq!(pool.collector.len(), 0, "collector forgets the pool");

        // outage ends: workers reconnect, ads return, matching resumes
        pool.end_outage();
        run(&mut pool, 41 * MINUTE, 20);
        assert_eq!(pool.collector.len(), 6);
        assert_eq!(pool.schedd.running_count(), 6);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn eviction_requeues_at_checkpoint_and_resumes() {
        // the full eviction -> requeue -> resume flow: a worker dies
        // mid-job, the job requeues at its checkpoint, resumes on a
        // fresh worker, and finishes after (overhead + remainder) only
        let mut pool = CondorPool::new().with_checkpoint(
            crate::config::CheckpointPolicy::Interval {
                every_s: 10 * MINUTE,
                resume_overhead_s: 2 * MINUTE,
            },
        );
        add_worker(&mut pool, 0, 60, NatProfile::permissive("x"), 0);
        submit_jobs(&mut pool, 1, 60 * MINUTE);
        run(&mut pool, 0, 10);
        assert_eq!(pool.schedd.running_count(), 1);

        // the worker is lost 35 minutes into the attempt
        let started = pool
            .schedd
            .jobs()[0]
            .started_at
            .expect("job is running");
        let evict_at = started + 35 * MINUTE;
        let mut events = Vec::new();
        pool.remove_startd(SlotId::Cloud(InstanceId(0)), evict_at, &mut events);
        let job = &pool.schedd.jobs()[0];
        assert_eq!(job.completed_s, 30 * MINUTE, "3 checkpoints survive");
        assert_eq!(job.goodput_s, 30 * MINUTE);
        assert_eq!(job.badput_s, 5 * MINUTE);
        assert_eq!(
            pool.schedd.attempt_runtime(job.id),
            2 * MINUTE + 30 * MINUTE
        );

        // a replacement worker appears; the job resumes and completes
        add_worker(&mut pool, 1, 60, NatProfile::permissive("x"), evict_at);
        let events = run(&mut pool, evict_at + MINUTE, 45);
        assert!(events
            .iter()
            .any(|e| matches!(e, PoolEvent::JobCompleted(_))));
        assert_eq!(pool.schedd.stats.resumes, 1);
        let job = &pool.schedd.jobs()[0];
        assert_eq!(job.goodput_s, 60 * MINUTE, "goodput == runtime exactly");
        assert_eq!(job.badput_s, 5 * MINUTE + 2 * MINUTE);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn provider_work_attribution_matches_schedd_totals() {
        let mut pool = CondorPool::new();
        for i in 0..4 {
            add_worker(&mut pool, i, 60, NatProfile::permissive("x"), 0);
        }
        submit_jobs(&mut pool, 6, 30 * MINUTE);
        run(&mut pool, 0, 20);
        let mut events = Vec::new();
        pool.begin_outage(20 * MINUTE, &mut events);
        pool.end_outage();
        run(&mut pool, 21 * MINUTE, 60);
        // every settled wall second lands in exactly one provider bucket
        // (all workers here are Azure; on-prem none exist)
        let good: u64 = pool.stats.goodput_by_provider.iter().sum();
        let bad: u64 = pool.stats.badput_by_provider.iter().sum();
        assert_eq!(good, pool.schedd.stats.goodput_s);
        assert_eq!(bad, pool.schedd.stats.badput_s);
        assert_eq!(pool.stats.goodput_by_provider[0], 0, "no aws workers");
        assert!(pool.stats.goodput_by_provider[2] > 0, "azure did the work");
        pool.check_invariants().unwrap();
    }

    #[test]
    fn stats_track_cycles_and_matches() {
        let mut pool = CondorPool::new();
        for i in 0..2 {
            add_worker(&mut pool, i, 60, NatProfile::permissive("x"), 0);
        }
        submit_jobs(&mut pool, 2, 3600);
        run(&mut pool, 0, 11);
        assert!(pool.stats.negotiation_cycles >= 2);
        assert_eq!(pool.stats.matches, 2);
        assert!(pool.stats.classad_evaluations > 0);
    }
}
