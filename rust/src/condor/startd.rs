//! The startd: per-worker agent advertising a GPU slot and running jobs.
//!
//! Every cloud instance (and every on-prem GPU node) runs one startd with
//! a single T4 slot.  The startd holds a long-lived management connection
//! back to the central manager / schedd; on clouds that connection
//! traverses the region NAT — which is where the §IV Azure incident
//! lives: the default OSG keepalive (300 s) exceeded Azure's NAT idle
//! timeout (240 s), so the claim connection silently died between
//! keepalives and the running job was lost, every time.

use super::classad::{parse, Ad, Expr};
use super::job::JobId;
use crate::cloud::{InstanceId, Provider};
use crate::net::{Connection, NatProfile};
use crate::sim::SimTime;

/// Identifies a slot across cloud and on-prem resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SlotId {
    Cloud(InstanceId),
    OnPrem(u32),
}

impl std::fmt::Display for SlotId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SlotId::Cloud(id) => write!(f, "slot1@{id}"),
            SlotId::OnPrem(i) => write!(f, "slot1@onprem-{i}"),
        }
    }
}

/// An active claim: a job bound to this slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Claim {
    pub job: JobId,
    pub started_at: SimTime,
    pub finish_at: SimTime,
}

/// The worker agent.
#[derive(Debug)]
pub struct Startd {
    pub slot: SlotId,
    pub ad: Ad,
    pub start_expr: Expr,
    pub conn: Connection,
    pub keepalive_s: u64,
    pub next_keepalive: SimTime,
    pub claim: Option<Claim>,
    /// When a dropped connection may be retried.
    pub reconnect_at: Option<SimTime>,
    /// Pool provenance tag ("cloud" / "onprem") — Fig 2 accounting.
    pub pool_tag: &'static str,
    pub provider: Option<Provider>,
}

/// The default OSG worker configuration of the paper's first attempt:
/// 5-minute keepalives (fails on Azure's default NAT).
pub const OSG_DEFAULT_KEEPALIVE_S: u64 = 300;
/// The fixed configuration deployed after the incident.
pub const TUNED_KEEPALIVE_S: u64 = 60;
/// Reconnect backoff after a dropped management connection.
pub const RECONNECT_DELAY_S: u64 = 30;

/// Build the machine ad for a single-T4 worker.
pub fn t4_machine_ad(
    slot: SlotId,
    pool_tag: &'static str,
    provider: Option<Provider>,
    region_name: &str,
) -> Ad {
    let mut ad = Ad::new();
    ad.set_str("machine", &slot.to_string())
        .set_bool("hasgpu", true)
        .set_str("gpudevicename", "Tesla T4")
        .set_float("cudacapability", 7.5)
        .set_int("totalgpus", 1)
        .set_int("memory", 16384)
        .set_int("cpus", 4)
        .set_str("pool", pool_tag)
        .set_str("region", region_name);
    if let Some(p) = provider {
        ad.set_str("provider", p.name());
    }
    ad
}

/// The pool's START policy: the CE only admits IceCube jobs, and the
/// glideins inherit that restriction.
pub fn icecube_start_expr() -> Expr {
    parse("TARGET.Owner == \"icecube\"").expect("static expression parses")
}

impl Startd {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        slot: SlotId,
        pool_tag: &'static str,
        provider: Option<Provider>,
        region_name: &str,
        nat: NatProfile,
        keepalive_s: u64,
        now: SimTime,
    ) -> Self {
        Startd {
            slot,
            ad: t4_machine_ad(slot, pool_tag, provider, region_name),
            start_expr: icecube_start_expr(),
            conn: Connection::establish(now, nat),
            keepalive_s,
            next_keepalive: now + keepalive_s,
            claim: None,
            reconnect_at: None,
            pool_tag,
            provider,
        }
    }

    pub fn is_unclaimed(&self) -> bool {
        self.claim.is_none() && self.conn.alive
    }

    /// Claim the slot for a job.  `runtime_s` is the wall time this
    /// attempt will occupy the slot (for a resumed job: restore
    /// overhead + the not-yet-checkpointed remainder, priced by
    /// `Schedd::attempt_runtime`).
    pub fn claim_for(&mut self, job: JobId, now: SimTime, runtime_s: u64) {
        debug_assert!(self.claim.is_none(), "double claim on {}", self.slot);
        self.claim = Some(Claim {
            job,
            started_at: now,
            finish_at: now + runtime_s,
        });
    }

    /// Release the claim (completion or interruption).
    pub fn release(&mut self) -> Option<Claim> {
        self.claim.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::SendOutcome;

    fn startd(keepalive: u64, nat: NatProfile) -> Startd {
        Startd::new(
            SlotId::Cloud(InstanceId(1)),
            "cloud",
            Some(Provider::Azure),
            "azure/eastus",
            nat,
            keepalive,
            0,
        )
    }

    #[test]
    fn machine_ad_matches_gpu_requirements() {
        let s = startd(60, NatProfile::azure_default());
        let req = super::super::job::gpu_requirements();
        let job_ad = super::super::job::gpu_job_ad("icecube", 8192);
        assert!(req.matches(&job_ad, Some(&s.ad)));
    }

    #[test]
    fn start_expr_admits_only_icecube() {
        let s = startd(60, NatProfile::azure_default());
        let ice = super::super::job::gpu_job_ad("icecube", 8192);
        let cms = super::super::job::gpu_job_ad("cms", 8192);
        assert!(s.start_expr.matches(&s.ad, Some(&ice)));
        assert!(!s.start_expr.matches(&s.ad, Some(&cms)));
    }

    #[test]
    fn claim_lifecycle() {
        let mut s = startd(60, NatProfile::azure_default());
        assert!(s.is_unclaimed());
        s.claim_for(JobId(5), 100, 3600);
        assert!(!s.is_unclaimed());
        let c = s.release().unwrap();
        assert_eq!(c.job, JobId(5));
        assert_eq!(c.finish_at, 3700);
        assert!(s.is_unclaimed());
    }

    #[test]
    fn osg_default_keepalive_dies_on_azure_nat() {
        // one full keepalive period at the OSG default: mapping is gone
        let mut s = startd(OSG_DEFAULT_KEEPALIVE_S, NatProfile::azure_default());
        let outcome = s.conn.try_send(s.next_keepalive);
        assert_eq!(outcome, SendOutcome::DroppedByNat);
    }

    #[test]
    fn tuned_keepalive_survives_azure_nat() {
        let mut s = startd(TUNED_KEEPALIVE_S, NatProfile::azure_default());
        let mut t = 0;
        for _ in 0..100 {
            t += TUNED_KEEPALIVE_S;
            assert_eq!(s.conn.try_send(t), SendOutcome::Delivered);
        }
    }

    #[test]
    fn slot_display() {
        assert_eq!(SlotId::Cloud(InstanceId(3)).to_string(), "slot1@vm-3");
        assert_eq!(SlotId::OnPrem(7).to_string(), "slot1@onprem-7");
    }
}
