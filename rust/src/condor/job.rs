//! Jobs and the job queue state machine.

use super::classad::{Ad, Expr};
use crate::sim::SimTime;

/// Unique job identifier (monotonic per schedd).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Job lifecycle (the subset of HTCondor's states we exercise).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Idle,
    Running,
    Completed,
    Removed,
}

/// One IceCube task: a photon-propagation workload unit.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub owner: String,
    pub submitted_at: SimTime,
    /// Ground-truth execution time on a T4 (seconds).
    pub runtime_s: u64,
    /// Total fp32 FLOPs the job performs (for EFLOP-hour accounting).
    pub flops: f64,
    /// Photon bunches the job propagates (drives real-compute sampling).
    pub bunches: u32,
    pub state: JobState,
    /// Scheduling attempts so far (1 + number of restarts).
    pub attempts: u32,
    /// Start of the current attempt.
    pub started_at: Option<SimTime>,
    pub completed_at: Option<SimTime>,
    /// Productive wall seconds: work that counted toward the job's
    /// ground-truth runtime (completed attempts plus checkpointed
    /// progress salvaged from interrupted ones).
    pub goodput_s: u64,
    /// Wall seconds wasted: lost un-checkpointed tails of interrupted
    /// attempts plus checkpoint-restore overheads.
    pub badput_s: u64,
    /// Progress (seconds of ground-truth runtime) safely checkpointed;
    /// the next attempt resumes here instead of zero.  Always a
    /// multiple of the checkpoint interval; 0 under
    /// `CheckpointPolicy::None` (the paper baseline).
    pub completed_s: u64,
    /// `completed_s` at the start of the current attempt.
    pub attempt_base_s: u64,
    /// Checkpoint-restore overhead charged to the current attempt.
    pub attempt_overhead_s: u64,
    /// The job ad used in matchmaking.
    pub ad: Ad,
    /// Parsed Requirements expression.
    pub requirements: Expr,
    /// Cached autocluster signature (computing it per negotiation cycle
    /// dominated the campaign profile — see EXPERIMENTS.md §Perf).
    pub autocluster: String,
}

/// Autocluster signature: jobs with identical matchmaking inputs are
/// negotiated as one cluster. Computed once at submit.
pub fn autocluster_signature(requirements: &Expr, ad: &Ad) -> String {
    format!("{requirements:?}|{}", ad.signature())
}

impl Job {
    pub fn autocluster_key(&self) -> &str {
        &self.autocluster
    }

    /// Fraction of the ground-truth runtime already checkpointed.
    pub fn completed_fraction(&self) -> f64 {
        crate::workload::icecube::completed_fraction(
            self.completed_s,
            self.runtime_s,
        )
    }
}

/// Builder for IceCube-style GPU jobs.
pub fn gpu_job_ad(owner: &str, request_memory_mb: i64) -> Ad {
    let mut ad = Ad::new();
    ad.set_str("owner", owner)
        .set_int("requestgpus", 1)
        .set_int("requestmemory", request_memory_mb)
        .set_str("jobuniverse", "vanilla");
    ad
}

/// The standard IceCube GPU job Requirements expression.
pub fn gpu_requirements() -> Expr {
    super::classad::parse(
        "TARGET.HasGPU && TARGET.CUDACapability >= 6.0 \
         && TARGET.Memory >= MY.RequestMemory",
    )
    .expect("static expression parses")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64) -> Job {
        Job {
            id: JobId(id),
            owner: "icecube".into(),
            submitted_at: 0,
            runtime_s: 3600,
            flops: 1e15,
            bunches: 100,
            state: JobState::Idle,
            attempts: 0,
            started_at: None,
            completed_at: None,
            goodput_s: 0,
            badput_s: 0,
            completed_s: 0,
            attempt_base_s: 0,
            attempt_overhead_s: 0,
            ad: gpu_job_ad("icecube", 8192),
            requirements: gpu_requirements(),
            autocluster: autocluster_signature(
                &gpu_requirements(), &gpu_job_ad("icecube", 8192)),
        }
    }

    #[test]
    fn autocluster_groups_identical_jobs() {
        assert_eq!(job(1).autocluster_key(), job(2).autocluster_key());
        // a different matchmaking input yields a different signature
        let mut other = job(3);
        other.ad.set_int("requestmemory", 4096);
        other.autocluster =
            autocluster_signature(&other.requirements, &other.ad);
        assert_ne!(job(1).autocluster_key(), other.autocluster_key());
    }

    #[test]
    fn completed_fraction_tracks_checkpoint_state() {
        let mut j = job(1);
        assert_eq!(j.completed_fraction(), 0.0);
        j.completed_s = 1800;
        assert_eq!(j.completed_fraction(), 0.5);
    }

    #[test]
    fn requirements_need_gpu_machine() {
        let j = job(1);
        let mut machine = Ad::new();
        machine
            .set_bool("hasgpu", true)
            .set_float("cudacapability", 7.5)
            .set_int("memory", 16384);
        assert!(j.requirements.matches(&j.ad, Some(&machine)));
        machine.set_bool("hasgpu", false);
        assert!(!j.requirements.matches(&j.ad, Some(&machine)));
    }

    #[test]
    fn requirements_enforce_memory() {
        let j = job(1);
        let mut machine = Ad::new();
        machine
            .set_bool("hasgpu", true)
            .set_float("cudacapability", 7.5)
            .set_int("memory", 4096); // below the 8 GiB request
        assert!(!j.requirements.matches(&j.ad, Some(&machine)));
    }
}
