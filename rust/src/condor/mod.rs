//! HTCondor-style workload management substrate.
//!
//! A behaviourally-equivalent reimplementation of the slice of HTCondor
//! the paper's setup exercises: ClassAd matchmaking (`classad`), the
//! central manager (`collector`, `negotiator`), the job queue (`schedd`,
//! `job`) and the per-worker agent (`startd`), assembled by `pool`.
//! Cloud workers join the pool exactly like on-prem ones — the paper's
//! core integration claim.

pub mod classad;
pub mod collector;
pub mod job;
pub mod negotiator;
pub mod pool;
pub mod schedd;
pub mod startd;

pub use classad::{Ad, Expr, Value};
pub use collector::Collector;
pub use job::{Job, JobId, JobState};
pub use negotiator::CycleResult;
pub use pool::{CondorPool, InterruptCause, PoolEvent, PoolStats};
pub use schedd::{Schedd, ScheddStats, WorkDelta};
pub use startd::{Claim, SlotId, Startd};
