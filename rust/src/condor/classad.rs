//! Mini ClassAd language: attribute lists + matchmaking expressions.
//!
//! HTCondor's matchmaking is bilateral: a job ad carries a `Requirements`
//! expression evaluated against a machine ad, and the machine's `START`
//! expression is evaluated against the job ad.  This module implements
//! the subset that federated GPU pools actually use: typed attributes
//! (int/float/string/bool/undefined), `MY.`/`TARGET.` scoped references,
//! arithmetic, comparisons (case-insensitive string equality, like
//! HTCondor's `==`), and three-valued boolean logic where `Undefined`
//! propagates (an ad missing an attribute must not crash a negotiation
//! cycle — it just doesn't match).

use std::collections::BTreeMap;
use std::fmt;

/// A ClassAd attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    Undefined,
}

impl Value {
    fn as_num(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            Value::Bool(b) => Some(*b as i64 as f64),
            _ => None,
        }
    }

    /// Three-valued truthiness: Some(bool) or None for Undefined.
    fn as_tribool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            Value::Int(i) => Some(*i != 0),
            Value::Float(f) => Some(*f != 0.0),
            Value::Undefined => None,
            Value::Str(_) => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Undefined => write!(f, "undefined"),
        }
    }
}

/// An attribute list (one ad). Keys are case-insensitive like HTCondor's.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Ad {
    attrs: BTreeMap<String, Value>,
}

impl Ad {
    pub fn new() -> Self {
        Ad::default()
    }

    pub fn set(&mut self, key: &str, value: Value) -> &mut Self {
        self.attrs.insert(key.to_ascii_lowercase(), value);
        self
    }

    pub fn set_int(&mut self, key: &str, v: i64) -> &mut Self {
        self.set(key, Value::Int(v))
    }

    pub fn set_float(&mut self, key: &str, v: f64) -> &mut Self {
        self.set(key, Value::Float(v))
    }

    pub fn set_str(&mut self, key: &str, v: &str) -> &mut Self {
        self.set(key, Value::Str(v.to_string()))
    }

    pub fn set_bool(&mut self, key: &str, v: bool) -> &mut Self {
        self.set(key, Value::Bool(v))
    }

    pub fn get(&self, key: &str) -> Value {
        self.attrs
            .get(&key.to_ascii_lowercase())
            .cloned()
            .unwrap_or(Value::Undefined)
    }

    pub fn has(&self, key: &str) -> bool {
        self.attrs.contains_key(&key.to_ascii_lowercase())
    }

    pub fn len(&self) -> usize {
        self.attrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.attrs.is_empty()
    }

    /// Canonical string form (stable order) — used as autocluster signature.
    pub fn signature(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.attrs {
            s.push_str(k);
            s.push('=');
            s.push_str(&v.to_string());
            s.push(';');
        }
        s
    }
}

/// Expression AST.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Lit(Value),
    /// Attribute reference with optional scope (None = MY-then-TARGET).
    Attr(Option<Scope>, String),
    Not(Box<Expr>),
    Neg(Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    My,
    Target,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    And,
    Or,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Add,
    Sub,
    Mul,
    Div,
}

impl Expr {
    /// Evaluate in a matchmaking context.
    pub fn eval(&self, my: &Ad, target: Option<&Ad>) -> Value {
        match self {
            Expr::Lit(v) => v.clone(),
            Expr::Attr(scope, name) => match scope {
                Some(Scope::My) => my.get(name),
                Some(Scope::Target) => {
                    target.map(|t| t.get(name)).unwrap_or(Value::Undefined)
                }
                None => {
                    let v = my.get(name);
                    if v == Value::Undefined {
                        target.map(|t| t.get(name)).unwrap_or(Value::Undefined)
                    } else {
                        v
                    }
                }
            },
            Expr::Not(e) => match e.eval(my, target).as_tribool() {
                Some(b) => Value::Bool(!b),
                None => Value::Undefined,
            },
            Expr::Neg(e) => match e.eval(my, target) {
                Value::Int(i) => Value::Int(-i),
                Value::Float(f) => Value::Float(-f),
                _ => Value::Undefined,
            },
            Expr::Bin(op, a, b) => {
                let av = a.eval(my, target);
                match op {
                    BinOp::And => match av.as_tribool() {
                        Some(false) => Value::Bool(false),
                        Some(true) => match b.eval(my, target).as_tribool() {
                            Some(v) => Value::Bool(v),
                            None => Value::Undefined,
                        },
                        None => match b.eval(my, target).as_tribool() {
                            Some(false) => Value::Bool(false),
                            _ => Value::Undefined,
                        },
                    },
                    BinOp::Or => match av.as_tribool() {
                        Some(true) => Value::Bool(true),
                        Some(false) => match b.eval(my, target).as_tribool() {
                            Some(v) => Value::Bool(v),
                            None => Value::Undefined,
                        },
                        None => match b.eval(my, target).as_tribool() {
                            Some(true) => Value::Bool(true),
                            _ => Value::Undefined,
                        },
                    },
                    _ => {
                        let bv = b.eval(my, target);
                        eval_binop(*op, &av, &bv)
                    }
                }
            }
        }
    }

    /// Evaluate to bool with Undefined → false (top-level match semantics).
    pub fn matches(&self, my: &Ad, target: Option<&Ad>) -> bool {
        self.eval(my, target).as_tribool().unwrap_or(false)
    }
}

fn eval_binop(op: BinOp, a: &Value, b: &Value) -> Value {
    use BinOp::*;
    if *a == Value::Undefined || *b == Value::Undefined {
        return Value::Undefined;
    }
    // string equality is case-insensitive, like HTCondor's `==`
    if let (Value::Str(x), Value::Str(y)) = (a, b) {
        let c = x.to_ascii_lowercase().cmp(&y.to_ascii_lowercase());
        return match op {
            Eq => Value::Bool(c.is_eq()),
            Ne => Value::Bool(!c.is_eq()),
            Lt => Value::Bool(c.is_lt()),
            Le => Value::Bool(c.is_le()),
            Gt => Value::Bool(c.is_gt()),
            Ge => Value::Bool(c.is_ge()),
            _ => Value::Undefined,
        };
    }
    let (x, y) = match (a.as_num(), b.as_num()) {
        (Some(x), Some(y)) => (x, y),
        _ => return Value::Undefined,
    };
    let both_int = matches!((a, b), (Value::Int(_), Value::Int(_)));
    match op {
        Eq => Value::Bool(x == y),
        Ne => Value::Bool(x != y),
        Lt => Value::Bool(x < y),
        Le => Value::Bool(x <= y),
        Gt => Value::Bool(x > y),
        Ge => Value::Bool(x >= y),
        Add | Sub | Mul | Div => {
            let r = match op {
                Add => x + y,
                Sub => x - y,
                Mul => x * y,
                Div => {
                    if y == 0.0 {
                        return Value::Undefined;
                    }
                    x / y
                }
                _ => unreachable!(),
            };
            if both_int && r.fract() == 0.0 && op != Div {
                Value::Int(r as i64)
            } else {
                Value::Float(r)
            }
        }
        And | Or => unreachable!("handled in eval"),
    }
}

// --- parser ----------------------------------------------------------------

/// Parse a ClassAd expression.
pub fn parse(src: &str) -> Result<Expr, String> {
    let tokens = lex(src)?;
    let mut p = P { t: &tokens, i: 0 };
    let e = p.or_expr()?;
    if p.i != tokens.len() {
        return Err(format!("trailing tokens at {:?}", &tokens[p.i..]));
    }
    Ok(e)
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(f64, bool), // value, is_int
    Str(String),
    Ident(String),
    Op(&'static str),
}

fn lex(src: &str) -> Result<Vec<Tok>, String> {
    let b: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            '"' => {
                let mut s = String::new();
                i += 1;
                while i < b.len() && b[i] != '"' {
                    s.push(b[i]);
                    i += 1;
                }
                if i == b.len() {
                    return Err("unterminated string".into());
                }
                i += 1;
                out.push(Tok::Str(s));
            }
            '0'..='9' => {
                let start = i;
                let mut is_int = true;
                while i < b.len() && (b[i].is_ascii_digit() || b[i] == '.'
                    || b[i] == 'e' || b[i] == 'E'
                    || ((b[i] == '+' || b[i] == '-')
                        && matches!(b[i - 1], 'e' | 'E')))
                {
                    if b[i] == '.' || b[i] == 'e' || b[i] == 'E' {
                        is_int = false;
                    }
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                let v: f64 =
                    text.parse().map_err(|_| format!("bad number {text}"))?;
                out.push(Tok::Num(v, is_int));
            }
            'a'..='z' | 'A'..='Z' | '_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == '_')
                {
                    i += 1;
                }
                out.push(Tok::Ident(b[start..i].iter().collect()));
            }
            '&' if b.get(i + 1) == Some(&'&') => {
                out.push(Tok::Op("&&"));
                i += 2;
            }
            '|' if b.get(i + 1) == Some(&'|') => {
                out.push(Tok::Op("||"));
                i += 2;
            }
            '=' if b.get(i + 1) == Some(&'=') => {
                out.push(Tok::Op("=="));
                i += 2;
            }
            '!' if b.get(i + 1) == Some(&'=') => {
                out.push(Tok::Op("!="));
                i += 2;
            }
            '<' if b.get(i + 1) == Some(&'=') => {
                out.push(Tok::Op("<="));
                i += 2;
            }
            '>' if b.get(i + 1) == Some(&'=') => {
                out.push(Tok::Op(">="));
                i += 2;
            }
            '<' => {
                out.push(Tok::Op("<"));
                i += 1;
            }
            '>' => {
                out.push(Tok::Op(">"));
                i += 1;
            }
            '!' => {
                out.push(Tok::Op("!"));
                i += 1;
            }
            '+' => {
                out.push(Tok::Op("+"));
                i += 1;
            }
            '-' => {
                out.push(Tok::Op("-"));
                i += 1;
            }
            '*' => {
                out.push(Tok::Op("*"));
                i += 1;
            }
            '/' => {
                out.push(Tok::Op("/"));
                i += 1;
            }
            '(' => {
                out.push(Tok::Op("("));
                i += 1;
            }
            ')' => {
                out.push(Tok::Op(")"));
                i += 1;
            }
            '.' => {
                out.push(Tok::Op("."));
                i += 1;
            }
            c => return Err(format!("unexpected character '{c}'")),
        }
    }
    Ok(out)
}

struct P<'a> {
    t: &'a [Tok],
    i: usize,
}

impl<'a> P<'a> {
    fn peek(&self) -> Option<&Tok> {
        self.t.get(self.i)
    }

    fn eat_op(&mut self, op: &str) -> bool {
        if self.peek() == Some(&Tok::Op(match op {
            "&&" => "&&",
            "||" => "||",
            "==" => "==",
            "!=" => "!=",
            "<=" => "<=",
            ">=" => ">=",
            "<" => "<",
            ">" => ">",
            "!" => "!",
            "+" => "+",
            "-" => "-",
            "*" => "*",
            "/" => "/",
            "(" => "(",
            ")" => ")",
            "." => ".",
            _ => return false,
        })) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn or_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.and_expr()?;
        while self.eat_op("||") {
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.cmp_expr()?;
        while self.eat_op("&&") {
            let rhs = self.cmp_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, String> {
        let lhs = self.add_expr()?;
        for (tok, op) in [
            ("==", BinOp::Eq),
            ("!=", BinOp::Ne),
            ("<=", BinOp::Le),
            (">=", BinOp::Ge),
            ("<", BinOp::Lt),
            (">", BinOp::Gt),
        ] {
            if self.eat_op(tok) {
                let rhs = self.add_expr()?;
                return Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)));
            }
        }
        Ok(lhs)
    }

    fn add_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.mul_expr()?;
        loop {
            if self.eat_op("+") {
                let rhs = self.mul_expr()?;
                lhs = Expr::Bin(BinOp::Add, Box::new(lhs), Box::new(rhs));
            } else if self.eat_op("-") {
                let rhs = self.mul_expr()?;
                lhs = Expr::Bin(BinOp::Sub, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn mul_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.unary_expr()?;
        loop {
            if self.eat_op("*") {
                let rhs = self.unary_expr()?;
                lhs = Expr::Bin(BinOp::Mul, Box::new(lhs), Box::new(rhs));
            } else if self.eat_op("/") {
                let rhs = self.unary_expr()?;
                lhs = Expr::Bin(BinOp::Div, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn unary_expr(&mut self) -> Result<Expr, String> {
        if self.eat_op("!") {
            return Ok(Expr::Not(Box::new(self.unary_expr()?)));
        }
        if self.eat_op("-") {
            return Ok(Expr::Neg(Box::new(self.unary_expr()?)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Expr, String> {
        match self.peek().cloned() {
            Some(Tok::Num(v, is_int)) => {
                self.i += 1;
                Ok(Expr::Lit(if is_int {
                    Value::Int(v as i64)
                } else {
                    Value::Float(v)
                }))
            }
            Some(Tok::Str(s)) => {
                self.i += 1;
                Ok(Expr::Lit(Value::Str(s)))
            }
            Some(Tok::Ident(name)) => {
                self.i += 1;
                let lower = name.to_ascii_lowercase();
                match lower.as_str() {
                    "true" => return Ok(Expr::Lit(Value::Bool(true))),
                    "false" => return Ok(Expr::Lit(Value::Bool(false))),
                    "undefined" => return Ok(Expr::Lit(Value::Undefined)),
                    _ => {}
                }
                // scope prefix?
                if (lower == "my" || lower == "target") && self.eat_op(".") {
                    match self.peek().cloned() {
                        Some(Tok::Ident(attr)) => {
                            self.i += 1;
                            let scope = if lower == "my" {
                                Scope::My
                            } else {
                                Scope::Target
                            };
                            Ok(Expr::Attr(Some(scope), attr))
                        }
                        _ => Err("expected attribute after scope".into()),
                    }
                } else {
                    Ok(Expr::Attr(None, name))
                }
            }
            Some(Tok::Op("(")) => {
                self.i += 1;
                let e = self.or_expr()?;
                if !self.eat_op(")") {
                    return Err("expected ')'".into());
                }
                Ok(e)
            }
            other => Err(format!("unexpected token {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine_ad() -> Ad {
        let mut ad = Ad::new();
        ad.set_str("machine", "vm-17.eastus.azure")
            .set_bool("hasgpu", true)
            .set_str("gpudevicename", "Tesla T4")
            .set_float("cudacapability", 7.5)
            .set_int("memory", 16384)
            .set_str("pool", "cloud")
            .set_str("provider", "azure");
        ad
    }

    fn job_ad() -> Ad {
        let mut ad = Ad::new();
        ad.set_str("owner", "icecube")
            .set_int("requestgpus", 1)
            .set_int("requestmemory", 8192);
        ad
    }

    #[test]
    fn literal_eval() {
        let e = parse("2 + 3 * 4").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Int(14));
    }

    #[test]
    fn precedence_and_parens() {
        let e = parse("(2 + 3) * 4").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Int(20));
        let e = parse("1 + 2 == 3 && 2 < 3").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Bool(true));
    }

    #[test]
    fn job_requirements_match_t4_machine() {
        let req = parse(
            "TARGET.HasGPU && TARGET.CUDACapability >= 6.0 \
             && TARGET.Memory >= MY.RequestMemory",
        )
        .unwrap();
        assert!(req.matches(&job_ad(), Some(&machine_ad())));
    }

    #[test]
    fn start_expression_gates_on_owner() {
        // the CE policy: only IceCube jobs may run
        let start = parse("TARGET.Owner == \"icecube\"").unwrap();
        assert!(start.matches(&machine_ad(), Some(&job_ad())));
        let mut other = job_ad();
        other.set_str("owner", "cms");
        assert!(!start.matches(&machine_ad(), Some(&other)));
    }

    #[test]
    fn string_equality_case_insensitive() {
        let e = parse("GPUDeviceName == \"tesla t4\"").unwrap();
        assert!(e.matches(&machine_ad(), None));
    }

    #[test]
    fn undefined_attribute_does_not_match() {
        let req = parse("TARGET.NoSuchAttr >= 5").unwrap();
        assert!(!req.matches(&job_ad(), Some(&machine_ad())));
    }

    #[test]
    fn undefined_propagation_three_valued() {
        let my = Ad::new();
        // undefined && false == false; undefined && true == undefined
        let e = parse("NoSuch && false").unwrap();
        assert_eq!(e.eval(&my, None), Value::Bool(false));
        let e = parse("NoSuch && true").unwrap();
        assert_eq!(e.eval(&my, None), Value::Undefined);
        let e = parse("NoSuch || true").unwrap();
        assert_eq!(e.eval(&my, None), Value::Bool(true));
        let e = parse("NoSuch || false").unwrap();
        assert_eq!(e.eval(&my, None), Value::Undefined);
    }

    #[test]
    fn bare_attr_falls_back_to_target() {
        let e = parse("HasGPU").unwrap();
        assert!(e.matches(&job_ad(), Some(&machine_ad())));
    }

    #[test]
    fn my_scope_does_not_leak_to_target() {
        let e = parse("MY.HasGPU").unwrap();
        assert!(!e.matches(&job_ad(), Some(&machine_ad())));
    }

    #[test]
    fn negation_and_not() {
        let e = parse("!(1 > 2)").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Bool(true));
        let e = parse("-3 + 5").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Int(2));
    }

    #[test]
    fn division_by_zero_undefined() {
        let e = parse("1 / 0").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Undefined);
    }

    #[test]
    fn float_int_promotion() {
        let e = parse("3 / 2").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Float(1.5));
        let e = parse("Memory * 2 >= 32768").unwrap();
        assert!(e.matches(&machine_ad(), None));
    }

    #[test]
    fn ad_keys_case_insensitive() {
        let mut ad = Ad::new();
        ad.set_int("RequestGPUs", 1);
        assert_eq!(ad.get("requestgpus"), Value::Int(1));
        assert_eq!(ad.get("REQUESTGPUS"), Value::Int(1));
    }

    #[test]
    fn signature_stable_and_distinct() {
        let a = job_ad();
        let b = job_ad();
        assert_eq!(a.signature(), b.signature());
        let mut c = job_ad();
        c.set_int("requestmemory", 1);
        assert_ne!(a.signature(), c.signature());
    }

    #[test]
    fn parse_errors_reported() {
        assert!(parse("1 +").is_err());
        assert!(parse("(1").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 ~ 2").is_err());
        assert!(parse("a b").is_err());
    }

    #[test]
    fn booleans_and_undefined_literals() {
        let e = parse("TRUE && !FALSE").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Bool(true));
        let e = parse("undefined == 1").unwrap();
        assert_eq!(e.eval(&Ad::new(), None), Value::Undefined);
    }
}
