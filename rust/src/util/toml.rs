//! TOML-subset parser for configuration files.
//!
//! Supports the subset a launcher config actually needs: `[table]` and
//! `[dotted.table]` headers, `key = value` pairs with string / integer /
//! float / boolean / homogeneous-array values, `#` comments, and dotted
//! keys.  Parses into the [`Json`] tree (one value model everywhere), so
//! config lookup shares the same `get_path` API as artifact metadata.

use super::json::Json;
use std::collections::BTreeMap;

/// Parse error: line number (1-based) + message.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError { line, message: msg.into() }
}

/// Parse a TOML-subset document into a `Json::Obj` tree.
pub fn parse(input: &str) -> Result<Json, TomlError> {
    let mut root = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated table header"))?;
            if header.starts_with('[') {
                return Err(err(lineno, "array-of-tables not supported"));
            }
            current_path = split_dotted(header, lineno)?;
            // materialize the table so empty sections exist
            ensure_table(&mut root, &current_path, lineno)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key_part = line[..eq].trim();
        let val_part = line[eq + 1..].trim();
        if key_part.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        if val_part.is_empty() {
            return Err(err(lineno, "empty value"));
        }
        let mut path = current_path.clone();
        path.extend(split_dotted(key_part, lineno)?);
        let value = parse_value(val_part, lineno)?;
        insert(&mut root, &path, value, lineno)?;
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn split_dotted(s: &str, lineno: usize) -> Result<Vec<String>, TomlError> {
    let parts: Vec<String> =
        s.split('.').map(|p| p.trim().trim_matches('"').to_string()).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(err(lineno, "empty path segment"));
    }
    Ok(parts)
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Json>, TomlError> {
    let mut cur = root;
    for seg in path {
        let entry =
            cur.entry(seg.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => return Err(err(lineno, format!("'{seg}' is not a table"))),
        };
    }
    Ok(cur)
}

fn insert(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    value: Json,
    lineno: usize,
) -> Result<(), TomlError> {
    let (last, parents) = path.split_last().expect("non-empty path");
    let table = ensure_table(root, parents, lineno)?;
    if table.contains_key(last) {
        return Err(err(lineno, format!("duplicate key '{last}'")));
    }
    table.insert(last.clone(), value);
    Ok(())
}

fn parse_value(s: &str, lineno: usize) -> Result<Json, TomlError> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(Json::Str(unescape(inner)));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(Json::Arr(items));
    }
    // numbers: allow underscores as TOML does
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(Json::Num(v as f64));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(Json::Num(v));
    }
    Err(err(lineno, format!("cannot parse value: {s}")))
}

/// Split array items at top-level commas (strings may contain commas).
fn split_array_items(s: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = r#"
# campaign config
seed = 42
name = "exercise"

[budget]
total_usd = 58000.0
alerts = [0.75, 0.5, 0.25, 0.1]

[cloud.azure]
enabled = true
regions = ["eastus", "westeurope"]
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get_path(&["seed"]).unwrap().as_u64(), Some(42));
        assert_eq!(v.get_path(&["name"]).unwrap().as_str(), Some("exercise"));
        assert_eq!(
            v.get_path(&["budget", "total_usd"]).unwrap().as_f64(),
            Some(58000.0)
        );
        assert_eq!(
            v.get_path(&["budget", "alerts"]).unwrap().as_arr().unwrap().len(),
            4
        );
        assert_eq!(
            v.get_path(&["cloud", "azure", "enabled"]).unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(
            v.get_path(&["cloud", "azure", "regions"])
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_str(),
            Some("eastus")
        );
    }

    #[test]
    fn dotted_keys() {
        let v = parse("a.b.c = 1").unwrap();
        assert_eq!(v.get_path(&["a", "b", "c"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn comments_inside_strings_kept() {
        let v = parse(r##"s = "a # not comment""##).unwrap();
        assert_eq!(v.get_path(&["s"]).unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn numbers_with_underscores() {
        let v = parse("n = 1_209_600").unwrap();
        assert_eq!(v.get_path(&["n"]).unwrap().as_u64(), Some(1_209_600));
    }

    #[test]
    fn negative_and_float() {
        let v = parse("a = -3\nb = 2.5e2").unwrap();
        assert_eq!(v.get_path(&["a"]).unwrap().as_f64(), Some(-3.0));
        assert_eq!(v.get_path(&["b"]).unwrap().as_f64(), Some(250.0));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn scalar_then_table_conflict_rejected() {
        assert!(parse("a = 1\n[a]\nb = 2").is_err());
    }

    #[test]
    fn bad_syntax_reports_line() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn empty_table_materialized() {
        let v = parse("[empty]\n[other]\nx = 1").unwrap();
        assert!(v.get_path(&["empty"]).unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#"s = "line1\nline2\t\"q\"""#).unwrap();
        assert_eq!(v.get_path(&["s"]).unwrap().as_str(), Some("line1\nline2\t\"q\""));
    }

    #[test]
    fn array_of_strings_with_commas() {
        let v = parse(r#"a = ["x,y", "z"]"#).unwrap();
        let arr = v.get_path(&["a"]).unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_str(), Some("x,y"));
        assert_eq!(arr[1].as_str(), Some("z"));
    }
}
