//! Tiny declarative CLI argument parser (no clap in the offline env).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! and positional arguments, with generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One option/flag specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A parsed argument set.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    /// Strict numeric option: absent is `Ok(None)`, present-but-
    /// malformed is an error — a mistyped value must never silently
    /// run a default (the contract `config::apply_toml` enforces for
    /// TOML knobs).  New numeric flags should prefer this over
    /// [`get_u64`](Args::get_u64), whose `parse().ok()` drops garbage.
    pub fn require_u64(&self, name: &str) -> Result<Option<u64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<u64>().map(Some).map_err(|_| {
                format!("--{name} must be an unsigned integer (got '{raw}')")
            }),
        }
    }

    /// Strict float option; same contract as [`require_u64`](Args::require_u64).
    pub fn require_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<f64>().map(Some).map_err(|_| {
                format!("--{name} must be a number (got '{raw}')")
            }),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// CLI command description: options + flags + positional docs.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    /// Parse the arguments following the subcommand name.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        // seed defaults
        for spec in &self.opts {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    out.values.insert(key.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\noptions:");
        for o in &self.opts {
            let meta = if o.takes_value { " <value>" } else { "" };
            let def = match o.default {
                Some(d) => format!(" [default: {d}]"),
                None => String::new(),
            };
            let _ = writeln!(s, "  --{}{}\n      {}{}", o.name, meta, o.help, def);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("campaign", "run the two-week campaign")
            .opt("seed", "rng seed", Some("42"))
            .opt("out", "output dir", None)
            .flag("real-compute", "execute PJRT artifacts")
    }

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get("out"), None);
        assert!(!a.flag("real-compute"));
    }

    #[test]
    fn require_u64_is_strict() {
        let a = cmd().parse(&argv(&["--seed", "7"])).unwrap();
        assert_eq!(a.require_u64("seed").unwrap(), Some(7));
        // absent (no default) is None, not an error
        assert_eq!(a.require_u64("out").unwrap(), None);
        // present-but-malformed must error, never silently default
        let a = cmd().parse(&argv(&["--seed", "3oo"])).unwrap();
        let err = a.require_u64("seed").unwrap_err();
        assert!(err.contains("--seed") && err.contains("3oo"), "{err}");
    }

    #[test]
    fn require_f64_is_strict() {
        let a = cmd().parse(&argv(&["--seed", "0.25"])).unwrap();
        assert_eq!(a.require_f64("seed").unwrap(), Some(0.25));
        assert_eq!(a.require_f64("out").unwrap(), None);
        let a = cmd().parse(&argv(&["--seed", "fast"])).unwrap();
        let err = a.require_f64("seed").unwrap_err();
        assert!(err.contains("--seed") && err.contains("fast"), "{err}");
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&argv(&["--seed", "7", "--out=results"])).unwrap();
        assert_eq!(a.get_u64("seed"), Some(7));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = cmd().parse(&argv(&["--real-compute", "extra1", "extra2"])).unwrap();
        assert!(a.flag("real-compute"));
        assert_eq!(a.positional(), &["extra1".to_string(), "extra2".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&argv(&["--out"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--real-compute=yes"])).is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--seed"));
        assert!(h.contains("default: 42"));
    }
}
