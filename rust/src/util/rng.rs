//! Deterministic PRNG and sampling distributions for the simulator.
//!
//! The whole campaign replay must be reproducible from a single seed
//! (EXPERIMENTS.md records seeds next to results), so every stochastic
//! subsystem draws from a [`Rng`] that is explicitly threaded through —
//! never from global state.  The generator is xoshiro256++, seeded via
//! SplitMix64 like the reference implementation.

/// xoshiro256++ PRNG (Blackman & Vigna), deterministic and splittable.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child generator for a named subsystem.
    ///
    /// Streams derived with different tags are decorrelated; deriving is
    /// how the campaign hands each subsystem (markets, workload, startds,
    /// ...) its own reproducible randomness.
    pub fn derive(&self, tag: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in tag.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut sm = self.s[0] ^ h.rotate_left(17) ^ self.s[2];
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw u64 (xoshiro256++ step).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // multiply-shift; bias is negligible for simulator purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Bernoulli trial with probability `p` (clamped to [0,1]).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with the given mean (> 0).
    pub fn exp(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal sample parameterized by the *target* median and the
    /// log-space sigma (matches how job runtimes are usually quoted).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        debug_assert!(median > 0.0);
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = self.gaussian(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick a uniformly random element (None on empty slice).
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> Option<&'a T> {
        if items.is_empty() {
            None
        } else {
            Some(&items[self.below(items.len() as u64) as usize])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut r = Rng::new(0);
        let v: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
        assert!(v.iter().any(|&x| x != 0));
    }

    #[test]
    fn derive_decorrelates() {
        let root = Rng::new(7);
        let mut a = root.derive("market");
        let mut b = root.derive("workload");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_is_stable() {
        let root = Rng::new(7);
        assert_eq!(root.derive("x").next_u64(), root.derive("x").next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(6);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(10);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(3600.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median / 3600.0 - 1.0).abs() < 0.05, "median={median}");
    }

    #[test]
    fn poisson_mean_small_lambda() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_mean_large_lambda() {
        let mut r = Rng::new(12);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(200.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 200.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(13);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(14);
        let mut v: Vec<u32> = (0..32).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
        assert_ne!(v, (0..32).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_empty_none() {
        let mut r = Rng::new(15);
        let empty: [u8; 0] = [];
        assert!(r.choose(&empty).is_none());
    }
}
