//! Leveled logger with simulated-time prefixes.
//!
//! The coordinator logs in *simulation time* (day/hh:mm of the campaign),
//! which is what an operator would see in the monitoring dashboards.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

static THRESHOLD: AtomicU8 = AtomicU8::new(Level::Info as u8);
static SINK: Mutex<Option<Vec<String>>> = Mutex::new(None);

/// Set the global verbosity threshold.
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" | "warning" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

/// Route log lines into an in-memory buffer (tests) instead of stderr.
pub fn capture(enable: bool) {
    let mut sink = SINK.lock().unwrap();
    *sink = if enable { Some(Vec::new()) } else { None };
}

/// Drain captured lines (empty if capture is off).
pub fn drain_captured() -> Vec<String> {
    let mut sink = SINK.lock().unwrap();
    match sink.as_mut() {
        Some(buf) => std::mem::take(buf),
        None => Vec::new(),
    }
}

/// Log a message stamped with simulated time (seconds since campaign start).
pub fn log(level: Level, sim_secs: u64, component: &str, msg: &str) {
    if (level as u8) < THRESHOLD.load(Ordering::Relaxed) {
        return;
    }
    let line = format!(
        "[{} {}] {:<12} {}",
        sim_day_hms(sim_secs),
        level.tag(),
        component,
        msg
    );
    let mut sink = SINK.lock().unwrap();
    match sink.as_mut() {
        Some(buf) => buf.push(line),
        None => {
            let _ = writeln!(std::io::stderr(), "{line}");
        }
    }
}

/// Format simulated seconds as `dD hh:mm:ss`.
pub fn sim_day_hms(sim_secs: u64) -> String {
    let days = sim_secs / 86_400;
    let rem = sim_secs % 86_400;
    format!(
        "d{:02} {:02}:{:02}:{:02}",
        days,
        rem / 3600,
        (rem % 3600) / 60,
        rem % 60
    )
}

#[macro_export]
macro_rules! sim_info {
    ($now:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $now, $comp,
                                  &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! sim_warn {
    ($now:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $now, $comp,
                                  &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! sim_debug {
    ($now:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $now, $comp,
                                  &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn day_hms_formatting() {
        assert_eq!(sim_day_hms(0), "d00 00:00:00");
        assert_eq!(sim_day_hms(86_400 + 3661), "d01 01:01:01");
        assert_eq!(sim_day_hms(13 * 86_400 + 86_399), "d13 23:59:59");
    }

    #[test]
    fn level_parsing() {
        assert_eq!(level_from_str("debug"), Some(Level::Debug));
        assert_eq!(level_from_str("WARN"), Some(Level::Warn));
        assert_eq!(level_from_str("nope"), None);
    }

    #[test]
    fn capture_and_threshold() {
        capture(true);
        set_level(Level::Info);
        log(Level::Debug, 0, "test", "hidden");
        log(Level::Warn, 60, "test", "shown");
        let lines = drain_captured();
        capture(false);
        set_level(Level::Info);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("shown"));
        assert!(lines[0].contains("d00 00:01:00"));
    }
}
