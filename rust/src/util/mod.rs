//! Offline-environment substrates: the small libraries `icecloud` would
//! normally pull from crates.io (serde/clap/criterion/proptest are not
//! available in the hermetic build), implemented in-tree.

pub mod bench;
pub mod cli;
pub mod fxhash;
pub mod json;
pub mod logger;
pub mod proptest;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod toml;
