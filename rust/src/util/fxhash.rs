//! FxHash: the rustc hash function, for hot-path maps with small keys.
//!
//! The campaign profile showed ~25% of L3 time inside SipHash for
//! `HashMap<SlotId, _>` lookups (keepalives touch every worker every
//! tick). SipHash's DoS resistance buys nothing against our own
//! simulator, so the hot maps use this multiply-rotate hash instead
//! (identical to the `rustc-hash` crate's algorithm).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc FxHasher: one multiply-rotate round per word.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add_to_hash(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add_to_hash(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// Drop-in `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
/// Drop-in `HashSet` with the fast hasher.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_keys_distinct_hashes() {
        let h = |v: u64| {
            let mut hasher = FxHasher::default();
            hasher.write_u64(v);
            hasher.finish()
        };
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(h(i)), "collision at {i}");
        }
    }

    #[test]
    fn map_works_like_std() {
        let mut m: FxHashMap<u64, &str> = FxHashMap::default();
        m.insert(1, "a");
        m.insert(2, "b");
        assert_eq!(m.get(&1), Some(&"a"));
        assert_eq!(m.len(), 2);
        m.remove(&1);
        assert!(!m.contains_key(&1));
    }

    #[test]
    fn byte_slices_hash_consistently() {
        let h = |b: &[u8]| {
            let mut hasher = FxHasher::default();
            hasher.write(b);
            hasher.finish()
        };
        assert_eq!(h(b"hello"), h(b"hello"));
        assert_ne!(h(b"hello"), h(b"hellp"));
        assert_ne!(h(b"12345678"), h(b"123456789"));
    }
}
