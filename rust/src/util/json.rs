//! Minimal JSON tree, parser and writer.
//!
//! The offline build environment carries no serde, so `icecloud` ships its
//! own small JSON substrate: enough to read the AOT `artifacts/meta.json`
//! and to emit machine-readable experiment results.  Numbers are f64
//! (sufficient for every value we exchange); objects preserve insertion
//! order so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for stable output files.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["variants", "default", "file"])`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, Some(2), 0);
        out
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(v: f64, out: &mut String) {
    if v.is_nan() || v.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if v.fract() == 0.0 && v.abs() < 9e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
}

fn write_json(j: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    let (nl, pad, pad_in): (String, String, String) = match indent {
        Some(w) => (
            "\n".into(),
            " ".repeat(w * depth),
            " ".repeat(w * (depth + 1)),
        ),
        None => (String::new(), String::new(), String::new()),
    };
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(v) => write_num(*v, out),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&nl);
                out.push_str(&pad_in);
                write_json(item, out, indent, depth + 1);
            }
            out.push_str(&nl);
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&nl);
                out.push_str(&pad_in);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(v, out, indent, depth + 1);
            }
            out.push_str(&nl);
            out.push_str(&pad);
            out.push('}');
        }
    }
}

/// Typed extraction with a diagnostic: `what` names the key in the
/// caller's vocabulary (`'ramp.targets'`, `[scenario.a] budget_usd`).
/// Strict config parsing is built on these — a present-but-mistyped
/// value must error, never silently no-op, because an override that
/// doesn't apply would replay a different campaign than requested.
pub fn require_u64(v: &Json, what: &str) -> Result<u64, String> {
    v.as_u64()
        .ok_or_else(|| format!("{what} must be a non-negative integer"))
}

/// See [`require_u64`].
pub fn require_f64(v: &Json, what: &str) -> Result<f64, String> {
    v.as_f64().ok_or_else(|| format!("{what} must be a number"))
}

/// See [`require_u64`].
pub fn require_bool(v: &Json, what: &str) -> Result<bool, String> {
    v.as_bool().ok_or_else(|| format!("{what} must be a boolean"))
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Nesting bound for untrusted input: a few thousand `[`s would
/// otherwise overflow the recursive parser's stack.  128 is far beyond
/// any document this crate exchanges (artifact metadata, sweep specs,
/// server request bodies).
pub const MAX_DEPTH: usize = 128;

/// Parse a JSON document (full input must be consumed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let raw = &self.b[self.i + 1..self.i + 5];
                            // strict: exactly four hex digits (RFC 8259);
                            // from_str_radix alone would admit "+abc"
                            if !raw.iter().all(u8::is_ascii_hexdigit) {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(raw)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs are not needed for
                            // our artifact metadata
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let v: f64 =
            text.parse().map_err(|_| self.err("invalid number"))?;
        // "1e999" parses to +inf; JSON numbers are finite, and a NaN/Inf
        // would silently round-trip to `null` on re-serialization
        if !v.is_finite() {
            return Err(self.err("number out of range"));
        }
        Ok(Json::Num(v))
    }

    fn array(&mut self, depth: usize) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get_path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get_path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_real_meta_like() {
        let src = r#"{
          "artifact_version": 1,
          "variants": {
            "small": {"file": "photon_small.hlo.txt", "num_photons": 256,
                      "flops_estimate": 1680000.0}
          }
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.get_path(&["variants", "small", "num_photons"])
                .unwrap()
                .as_u64(),
            Some(256)
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let mut o = Json::obj();
        o.set("s", Json::from("line\n\"quoted\"\ttab\\"));
        let s = o.to_string_compact();
        assert_eq!(parse(&s).unwrap(), o);
    }

    #[test]
    fn unicode_string() {
        let v = parse(r#""é café ☃""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ☃"));
    }

    #[test]
    fn numbers_with_exponent() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn integer_emission_has_no_decimal() {
        assert_eq!(Json::from(3.0).to_string_compact(), "3");
        assert_eq!(Json::from(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn nan_emits_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn pretty_output_parses_back() {
        let mut o = Json::obj();
        o.set("arr", Json::from(vec![1u64, 2, 3]));
        o.set("nested", {
            let mut n = Json::obj();
            n.set("k", Json::from("v"));
            n
        });
        let s = o.to_string_pretty();
        assert!(s.contains('\n'));
        assert_eq!(parse(&s).unwrap(), o);
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let deep = "[".repeat(100_000);
        assert!(parse(&deep).is_err());
        let legal = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(parse(&legal).is_ok());
    }

    #[test]
    fn non_finite_numbers_rejected() {
        assert!(parse("1e999").is_err());
        assert!(parse("-1e999").is_err());
        assert!(parse("1e308").is_ok());
    }

    #[test]
    fn loose_unicode_escape_digits_rejected() {
        assert!(parse(r#""\u+12f""#).is_err());
        assert!(parse(r#""é""#).is_ok());
    }

    #[test]
    fn as_u64_rejects_fractional() {
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
    }
}
