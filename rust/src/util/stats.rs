//! Descriptive statistics helpers used by monitoring, benches and reports.

/// Running mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.max }
    }

    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }
}

/// Percentile of a sample (linear interpolation, q in [0,1]).
/// Returns NaN on an empty slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Sort a copy and compute several percentiles at once.
pub fn percentiles(values: &[f64], qs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = values.iter().copied().filter(|x| !x.is_nan()).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter().map(|&q| percentile(&v, q)).collect()
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to the
/// edge bins (monitoring wants totals preserved).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64)
            .floor()
            .clamp(0.0, (n - 1) as f64) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }
}

/// Exponentially-weighted moving average (control loops, spend rate).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_basic() {
        let mut r = Running::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 4);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert!((r.variance() - 1.25).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 4.0);
        assert!((r.sum() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn running_empty_is_nan() {
        let r = Running::new();
        assert!(r.mean().is_nan());
        assert!(r.variance().is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_nan() {
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn percentiles_unsorted_input() {
        let v = [3.0, 1.0, 2.0];
        let ps = percentiles(&v, &[0.0, 0.5, 1.0]);
        assert_eq!(ps, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(0.5);
        h.push(9.5);
        h.push(-100.0); // clamps to bin 0
        h.push(100.0); // clamps to last
        assert_eq!(h.count, 4);
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
    }

    #[test]
    fn histogram_bin_center() {
        let h = Histogram::new(0.0, 10.0, 10);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
        assert!((h.bin_center(9) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.value(), None);
        e.push(10.0);
        for _ in 0..32 {
            e.push(20.0);
        }
        assert!((e.value().unwrap() - 20.0).abs() < 1e-3);
    }
}
