//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! `forall` drives a generator + property closure for N cases from a
//! deterministic seed; on failure it greedily shrinks the counterexample
//! with a user-supplied shrinker before panicking with the minimal case.
//!
//! Used by `rust/tests/prop_*.rs` for coordinator/matchmaking invariants.

use super::rng::Rng;
use std::fmt::Debug;

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Convenience: fail with a formatted message when `cond` is false.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` on `cases` generated inputs; panics on the first (shrunk)
/// failure with a reproduction seed.
pub fn forall<T: Clone + Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    generate: impl Fn(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            let (min_input, min_msg, steps) =
                shrink_failure(input, first_msg, &shrink, &prop);
            panic!(
                "property '{name}' failed (seed={seed}, case={case_idx}, \
                 shrink_steps={steps}):\n  input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

/// Greedy shrinking: repeatedly take the first shrunk candidate that still
/// fails, up to a step budget.
fn shrink_failure<T: Clone + Debug>(
    mut input: T,
    mut msg: String,
    shrink: &impl Fn(&T) -> Vec<T>,
    prop: &impl Fn(&T) -> PropResult,
) -> (T, String, usize) {
    let mut steps = 0;
    'outer: while steps < 1000 {
        for candidate in shrink(&input) {
            if let Err(m) = prop(&candidate) {
                input = candidate;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (input, msg, steps)
}

/// No shrinking.
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrink a u64 toward zero (halving + decrement).
pub fn shrink_u64(v: &u64) -> Vec<u64> {
    let v = *v;
    let mut out = Vec::new();
    if v > 0 {
        out.push(v / 2);
        out.push(v - 1);
    }
    out.dedup();
    out
}

/// Shrink a vec by dropping halves, then single elements.
// &Vec (not &[T]): the signature must match `Fn(&T) -> Vec<T>` with
// `T = Vec<_>` so it can be passed straight to `forall` as a shrinker.
#[allow(clippy::ptr_arg)]
pub fn shrink_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut smaller = v.clone();
            smaller.remove(i);
            out.push(smaller);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(
            "sum-commutes",
            1,
            200,
            |r| (r.below(1000), r.below(1000)),
            no_shrink,
            |(a, b)| ensure(a + b == b + a, "addition must commute"),
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-small' failed")]
    fn failing_property_panics() {
        forall(
            "always-small",
            2,
            200,
            |r| r.below(1000),
            shrink_u64,
            |v| ensure(*v < 990, format!("{v} too big")),
        );
    }

    #[test]
    fn shrinking_finds_minimal_u64() {
        // capture the panic message and check the counterexample is minimal
        let result = std::panic::catch_unwind(|| {
            forall(
                "min-ce",
                3,
                500,
                |r| r.below(10_000),
                shrink_u64,
                |v| ensure(*v < 500, "too big"),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // greedy shrink must land exactly on the boundary value 500
        assert!(msg.contains("input: 500"), "got: {msg}");
    }

    #[test]
    fn shrink_vec_reduces() {
        let v = vec![1, 2, 3, 4];
        let cands = shrink_vec(&v);
        assert!(cands.iter().any(|c| c.len() == 2));
        assert!(cands.iter().any(|c| c.len() == 3));
    }

    #[test]
    fn deterministic_given_seed() {
        use std::cell::RefCell;
        let seen_a = RefCell::new(Vec::new());
        forall("collect-a", 7, 10, |r| r.below(100), no_shrink, |v| {
            seen_a.borrow_mut().push(*v);
            Ok(())
        });
        let seen_b = RefCell::new(Vec::new());
        forall("collect-b", 7, 10, |r| r.below(100), no_shrink, |v| {
            seen_b.borrow_mut().push(*v);
            Ok(())
        });
        assert_eq!(seen_a.into_inner(), seen_b.into_inner());
    }
}
