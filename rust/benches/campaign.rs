//! End-to-end campaign benchmarks (the L3 hot path).
//!
//! The key perf claim: the full two-week 2k-GPU campaign must replay
//! orders of magnitude faster than real time. We bench a 2-day slice at
//! several fleet scales and report simulated-days-per-second.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::coordinator::Campaign;
use icecloud::sim::DAY;
use icecloud::util::bench::Bench;

fn config(days: u64, gpus: u32, onprem: u32) -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = days * DAY;
    c.ramp = vec![RampStep { target: gpus, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = onprem;
    c.generator.min_backlog = (gpus as usize * 2).max(500);
    c
}

fn main() {
    let mut b = Bench::new();

    b.run_throughput("campaign/2day-200gpu", 2.0, "sim-days", || {
        Campaign::new(config(2, 200, 200)).run().schedd_stats.completed
    });

    b.run_throughput("campaign/2day-1000gpu", 2.0, "sim-days", || {
        Campaign::new(config(2, 1000, 1000)).run().schedd_stats.completed
    });

    b.run_throughput("campaign/2day-2000gpu-peak", 2.0, "sim-days", || {
        Campaign::new(config(2, 2000, 1150)).run().schedd_stats.completed
    });

    // one tick at scale (the inner-loop cost the profile optimizes)
    let mut paper = Campaign::new(config(30, 2000, 1150));
    for step in 0..3 * 1440 {
        paper.tick(step * 60);
    }
    let mut t = 3 * 1440 * 60;
    b.run_throughput("campaign/tick-at-2k-scale", 1.0, "ticks", || {
        paper.tick(t);
        t += 60;
    });

    b.finish();
}
