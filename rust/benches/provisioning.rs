//! Cloud-fleet benchmarks: group reconcile + market dynamics + policy.
//!
//! DESIGN.md §8 ablations: group-target reconciliation frequency and the
//! provider-preference distribution cost at the paper's 20-region scale.

use icecloud::cloud::{providers, CloudSim, RegionId};
use icecloud::config::{PolicyMode, ProviderWeights};
use icecloud::coordinator::distribute;
use icecloud::sim::MINUTE;
use icecloud::util::bench::Bench;
use icecloud::util::rng::Rng;

fn loaded_fleet(target_per_region: u32) -> CloudSim {
    let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
    for rid in 0..fleet.num_regions() {
        fleet.set_target(RegionId(rid as u32), target_per_region);
    }
    // warm to steady state
    for i in 0..30 {
        fleet.tick(i * MINUTE, MINUTE);
    }
    fleet
}

fn main() {
    let mut b = Bench::new();

    let mut fleet = loaded_fleet(100); // ~2k instances across 20 regions
    let mut t = 30 * MINUTE;
    b.run_throughput("fleet/tick-2k-instances", 20.0, "regions", || {
        let ev = fleet.tick(t, MINUTE);
        t += MINUTE;
        ev.len()
    });

    // reconcile-frequency ablation: 1-min vs 5-min cadence over 1 sim-hour
    for (label, period) in [("1min", MINUTE), ("5min", 5 * MINUTE)] {
        let mut f = loaded_fleet(100);
        let mut now = 30 * MINUTE;
        b.run(&format!("fleet/1h-reconcile-{label}"), || {
            let steps = 3600 / period;
            for _ in 0..steps {
                f.tick(now, period);
                now += period;
            }
        });
    }

    let fleet_ro = loaded_fleet(100);
    let paper = PolicyMode::Fixed(ProviderWeights {
        aws: 0.15,
        gcp: 0.15,
        azure: 0.7,
    });
    b.run_throughput("policy/distribute-2000-gpus", 20.0, "regions", || {
        distribute(2000, &fleet_ro, &paper, None).len()
    });

    b.run_throughput("policy/distribute-adaptive", 20.0, "regions", || {
        distribute(2000, &fleet_ro, &PolicyMode::Adaptive, None).len()
    });

    b.finish();
}
