//! Sweep-runner + photon-engine scaling benchmarks.
//!
//! Two perf claims live here, recorded in EXPERIMENTS.md §Perf and
//! gated by CI's `bench-baseline` job via `tools/bench_compare.sh`:
//!
//! * **sweep scaling** — campaign replays/sec vs worker thread count;
//!   replays share no simulation state, so scaling should track
//!   physical cores.
//! * **engine scaling** — photons/sec of the scalar reference walk vs
//!   the batched SoA engine at 1/2/4 threads, on the artifact "default"
//!   shape (4096 photons x 64 steps x 60 DOMs), synthetic metadata so
//!   no artifact build is required.  `engine/batched-*` pins the sweep
//!   to `SimdMode::Off` (the PR 3 baseline) and `engine/simd-*` runs the
//!   lane sweep, so the two implementations stay separately gated.  The
//!   standing claims: batched ≥ 2x scalar (`ICECLOUD_MIN_SPEEDUP`) and
//!   simd ≥ batched (`ICECLOUD_MIN_SIMD_SPEEDUP`) in bench_compare.
//!
//! Scalar and batched closures rebuild inputs per iteration with the
//! same wrapping seed sequence, so the comparison stays apples-to-apples.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::runtime::{
    build_inputs, ExecPlan, PhotonExecutable, SimdMode, VariantMeta,
};
use icecloud::sim::{DAY, HOUR};
use icecloud::sweep;
use icecloud::util::bench::Bench;

fn small_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 12 * HOUR;
    c.ramp = vec![RampStep { target: 60, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 40;
    c.generator.min_backlog = 150;
    c
}

fn main() {
    let mut b = Bench::new();
    let base = small_base();
    let scenarios = sweep::builtin_matrix();
    let replays = scenarios.len() as f64;

    for threads in [1usize, 2, 4, 8] {
        b.run_throughput(
            &format!("sweep/{}-scenarios-{threads}-threads", scenarios.len()),
            replays,
            "replays",
            || sweep::run_matrix(&base, &scenarios, threads).len(),
        );
    }

    // grid expansion alone (PR 9): parse + cartesian product + per-cell
    // validation of the 3-axis {4,4,4} acceptance grid, no replays
    let grid_spec = "[grid]\n\
                     preempt_multiplier = [1.0, 2.0, 4.0, 10.0]\n\
                     budget_usd = [14500.0, 29000.0, 58000.0, 116000.0]\n\
                     keepalive_s = [60, 120, 240, 300]\n";
    let mut grid_base = small_base();
    b.run_throughput("sweep/grid-expand-64", 64.0, "scenarios", || {
        sweep::parse_spec(grid_spec, &mut grid_base).unwrap().len()
    });

    // the PR 10 registry axes, same expansion machinery: a 64-slot
    // carve-up sweep and an 8x8 checkpoint-transfer plane — these pin
    // the cost of registry-table dispatch + validation per cell
    let slots_spec = format!(
        "[grid]\ngpu_slots_per_instance = [{}]\n",
        (1..=64)
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let mut slots_base = small_base();
    b.run_throughput(
        "sweep/grid-expand-gpu-slots-64",
        64.0,
        "scenarios",
        || sweep::parse_spec(&slots_spec, &mut slots_base).unwrap().len(),
    );
    let transfer_spec = "[grid]\n\
         checkpoint_every_s = [900]\n\
         checkpoint_size_gb = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0]\n\
         checkpoint_transfer_mbps = [50.0, 100.0, 200.0, 400.0, 800.0, 1600.0, 3200.0, 6400.0]\n";
    let mut transfer_base = small_base();
    b.run_throughput(
        "sweep/grid-expand-checkpoint-transfer-64",
        64.0,
        "scenarios",
        || {
            sweep::parse_spec(transfer_spec, &mut transfer_base)
                .unwrap()
                .len()
        },
    );

    // the artifact "default" shape, as synthetic metadata
    let exe = PhotonExecutable::from_meta(VariantMeta::synthetic(
        "bench-default",
        4096,
        512,
        60,
        64,
    ))
    .unwrap();
    let photons = exe.meta.num_photons as f64;

    let mut seed = 0u32;
    b.run_throughput("engine/scalar", photons, "photons", || {
        seed = seed.wrapping_add(1);
        let inputs = build_inputs(&exe.meta, seed, true);
        exe.run_scalar(&inputs).unwrap().detected()
    });

    for (label, simd) in
        [("batched", SimdMode::Off), ("simd", SimdMode::Lanes)]
    {
        for threads in [1usize, 2, 4] {
            let mut seed = 0u32;
            b.run_throughput(
                &format!("engine/{label}-{threads}t"),
                photons,
                "photons",
                || {
                    seed = seed.wrapping_add(1);
                    let inputs = build_inputs(&exe.meta, seed, true);
                    exe.run_with_plan(
                        &inputs,
                        ExecPlan { threads, bunch: 4096, simd },
                    )
                    .unwrap()
                    .detected()
                },
            );
        }
    }

    b.finish();
}
