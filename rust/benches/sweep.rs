//! Sweep-runner benchmarks: campaign replays/sec vs worker thread count.
//!
//! The sweep subsystem's perf claim is near-linear scaling up to the
//! core count, because replays share no simulation state.  We run the
//! built-in 10-scenario matrix at a reduced duration and report
//! replays/sec at 1/2/4/8 workers — EXPERIMENTS.md §Perf records the
//! scaling curve.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::sim::{DAY, HOUR};
use icecloud::sweep;
use icecloud::util::bench::Bench;

fn small_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 12 * HOUR;
    c.ramp = vec![RampStep { target: 60, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 40;
    c.generator.min_backlog = 150;
    c
}

fn main() {
    let mut b = Bench::new();
    let base = small_base();
    let scenarios = sweep::builtin_matrix();
    let replays = scenarios.len() as f64;

    for threads in [1usize, 2, 4, 8] {
        b.run_throughput(
            &format!("sweep/10-scenarios-{threads}-threads"),
            replays,
            "replays",
            || sweep::run_matrix(&base, &scenarios, threads).len(),
        );
    }

    b.finish();
}
