//! Negotiator benchmarks: matchmaking cost at campaign scale.
//!
//! DESIGN.md §8 ablation: autoclustered negotiation (one ClassAd
//! evaluation pair per cluster-slot) vs the naive per-job cost it
//! replaces. At the paper's scale — ~2k slots, thousands of idle jobs —
//! a negotiation cycle must stay well under the 300 s cycle period.

use icecloud::cloud::{InstanceId, Provider};
use icecloud::condor::job::{gpu_job_ad, gpu_requirements};
use icecloud::condor::negotiator::negotiate;
use icecloud::condor::startd::{SlotId, Startd};
use icecloud::condor::Schedd;
use icecloud::net::NatProfile;
use icecloud::util::bench::Bench;
use icecloud::util::fxhash::FxHashMap;

fn pool(n: u64) -> FxHashMap<SlotId, Startd> {
    (0..n)
        .map(|i| {
            let slot = SlotId::Cloud(InstanceId(i));
            (
                slot,
                Startd::new(
                    slot,
                    "cloud",
                    Some(Provider::Azure),
                    "azure/eastus",
                    NatProfile::permissive("bench"),
                    60,
                    0,
                ),
            )
        })
        .collect()
}

fn schedd(jobs: u64, clusters: u64) -> Schedd {
    let mut s = Schedd::new();
    for i in 0..jobs {
        // `clusters` distinct memory requests -> that many autoclusters
        let mem = 4096 + 1024 * (i % clusters) as i64;
        s.submit(
            "icecube",
            3600,
            1e15,
            100,
            gpu_job_ad("icecube", mem),
            gpu_requirements(),
            0,
        );
    }
    s
}

fn main() {
    let mut b = Bench::new();

    let startds = pool(2000);
    let s1 = schedd(10_000, 1);
    b.run_throughput("negotiate/2k-slots-10k-jobs-1-cluster", 2000.0, "matches", || {
        negotiate(&s1, &startds, startds.keys().copied(), usize::MAX).matches.len()
    });

    let s8 = schedd(10_000, 8);
    b.run_throughput("negotiate/2k-slots-10k-jobs-8-clusters", 2000.0, "matches", || {
        negotiate(&s8, &startds, startds.keys().copied(), usize::MAX).matches.len()
    });

    // the worst case autoclustering protects against: every job unique
    let s_unique = schedd(2_000, 2_000);
    b.run_throughput("negotiate/2k-slots-2k-unique-jobs", 2000.0, "matches", || {
        negotiate(&s_unique, &startds, startds.keys().copied(), usize::MAX).matches.len()
    });

    // per-cycle cost during the steady state (few idle jobs, full pool)
    let s_steady = schedd(100, 1);
    b.run_throughput("negotiate/steady-state-100-idle", 100.0, "matches", || {
        negotiate(&s_steady, &startds, startds.keys().copied(), usize::MAX)
            .matches
            .len()
    });

    b.finish();
}
