//! `icecloud serve` load generator: requests/sec cold vs cached vs
//! disk-tier vs async admission.
//!
//! Starts an in-process server on an ephemeral port (with a scratch
//! persistent store) and drives it with the in-tree HTTP client
//! (`server::http`).  "Cold" requests vary the scenario seed every
//! iteration, so every request forces a real campaign replay; "cached"
//! requests repeat one spec, so after the first replay every response
//! is served from the memory tier.  "disk-hit" clears the memory tier
//! before every fetch, so each request pays the full read-verify-
//! promote path of the persistent store; "async-submit" measures the
//! `202` admission fast path of `POST /sweep?mode=async`.  The
//! subsystem's perf claim — cached throughput ≥ 100x cold replay
//! throughput — is printed as an explicit ratio at the end.
//! "fleet-2w" re-runs the cold-replay shape with two in-process fleet
//! workers leasing the units over HTTP, so the line prices the whole
//! lease/heartbeat/complete round trip against local dispatch.
//! "events-stream-{0,4}sub" publishes onto the live event bus with no
//! subscribers and with four attached SSE streams, pricing the bus's
//! publishers-never-block contract.  "grid-submit" posts a 64-cell
//! `[grid]` spec whose replay is already cached, pricing the cartesian
//! expansion + key derivation on the request path.
//!
//! Regenerate the committed baseline (BENCH_pr9.json) with:
//!   tools/bench_baseline.sh

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{
    EventKind, FleetOptions, ServeConfig, Server, WorkerOptions,
};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::bench::Bench;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

/// A background SSE reader that drains `/events` until the server
/// closes the stream (on shutdown).
fn spawn_sse_reader(addr: &str) -> std::thread::JoinHandle<()> {
    let addr = addr.to_string();
    std::thread::spawn(move || {
        let mut s = TcpStream::connect(&addr).expect("connect sse");
        s.write_all(
            format!("GET /events HTTP/1.1\r\nHost: {addr}\r\n\r\n")
                .as_bytes(),
        )
        .expect("send sse request");
        let mut buf = [0u8; 16 * 1024];
        loop {
            match s.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    })
}

fn post_sweep(addr: &str, path: &str, spec: &str) -> u16 {
    let resp = client_request(
        addr,
        "POST",
        path,
        Some("application/toml"),
        spec.as_bytes(),
    )
    .expect("request");
    assert!(
        resp.status == 200 || resp.status == 202,
        "{}",
        resp.body_str()
    );
    resp.status
}

fn main() {
    let store_root = std::env::temp_dir().join(format!(
        "icecloud-serve-bench-{}",
        std::process::id()
    ));
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 4,
        replay_threads: 2,
        cache_bytes: 64 << 20,
        queue_max: 64,
        job_runners: 2,
        store_dir: Some(store_root.clone()),
        fleet: FleetOptions::default(),
        events_ring: 1024,
        sample_every_s: 5,
        jobs_keep: 1024,
        base: tiny_base(),
    })
    .expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = server.spawn().expect("spawn");

    let mut b = Bench::new();

    // every iteration a fresh seed: full replay per request
    let mut seed = 0u64;
    b.run_throughput("serve/sweep-cold-replay", 1.0, "requests", || {
        seed += 1;
        post_sweep(
            &addr,
            "/sweep",
            &format!("[scenario.cold]\nseed = {seed}\n"),
        )
    });

    // one spec repeated: replayed once, then pure memory-tier traffic
    let hot_spec = "[scenario.hot]\nseed = 424242\n";
    post_sweep(&addr, "/sweep", hot_spec); // warm
    b.run_throughput("serve/sweep-cached", 1.0, "requests", || {
        post_sweep(&addr, "/sweep", hot_spec)
    });

    // the same hot spec through the disk tier: flush the memory tier
    // every iteration so each request pays read + verify + promote
    b.run_throughput("serve/disk-hit", 1.0, "requests", || {
        handle.state().cache.clear_memory();
        post_sweep(&addr, "/sweep", hot_spec)
    });

    // async admission fast path: the result is already cached, so each
    // submit measures parse + key + dedup + 202, no background replay
    b.run_throughput("serve/async-submit", 1.0, "requests", || {
        post_sweep(&addr, "/sweep?mode=async", hot_spec)
    });

    // a 64-cell grid spec, replay already cached: each request pays
    // TOML parse + cartesian expansion + 64-row key derivation + the
    // memory-tier hit, i.e. the grid machinery itself under load
    let grid_spec = "[grid]\n\
                     seed = [1, 2, 3, 4]\n\
                     keepalive_s = [60, 120, 240, 300]\n\
                     preempt_multiplier = [1.0, 2.0, 4.0, 10.0]\n";
    post_sweep(&addr, "/sweep", grid_spec); // warm (64 replays)
    b.run_throughput("serve/grid-submit", 1.0, "requests", || {
        post_sweep(&addr, "/sweep", grid_spec)
    });

    // cold replays again, but dispatched to two fleet workers over the
    // lease/heartbeat protocol instead of the local replay pool
    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let opts = WorkerOptions {
                coordinator: addr.clone(),
                worker_id: format!("bench-w{i}"),
                slots: 1,
                poll: Duration::from_millis(5),
                fail_after_leases: None,
                engine_simd: icecloud::runtime::SimdMode::default(),
            };
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                icecloud::server::fleet::run_worker(&opts, &stop)
            })
        })
        .collect();
    while handle.state().fleet.stats().workers_registered < 2 {
        std::thread::sleep(Duration::from_millis(2));
    }
    b.run_throughput("serve/fleet-2w", 1.0, "requests", || {
        seed += 1;
        post_sweep(
            &addr,
            "/sweep",
            &format!("[scenario.fleet]\nseed = {seed}\n"),
        )
    });
    stop.store(true, Ordering::Relaxed);
    for w in workers {
        let _ = w.join().expect("worker thread");
    }

    // the bus contract priced: a publish with nobody watching is a
    // counter bump and a ring append...
    b.run_throughput("serve/events-stream-0sub", 1.0, "events", || {
        handle
            .state()
            .events
            .publish(EventKind::JobDone { id: "bench".to_string() })
    });

    // ...and four live SSE streams must not make it meaningfully worse
    let readers: Vec<_> =
        (0..4).map(|_| spawn_sse_reader(&addr)).collect();
    while handle.state().events.subscriber_count() < 4 {
        std::thread::sleep(Duration::from_millis(2));
    }
    b.run_throughput("serve/events-stream-4sub", 1.0, "events", || {
        handle
            .state()
            .events
            .publish(EventKind::JobDone { id: "bench".to_string() })
    });

    let results = b.results();
    let cold = results[0].throughput().unwrap_or(f64::NAN);
    let cached = results[1].throughput().unwrap_or(f64::NAN);
    println!(
        "\ncold {:.1} req/s, cached {:.1} req/s => cached/cold = {:.0}x \
         (target >= 100x)",
        cold,
        cached,
        cached / cold
    );

    b.finish();
    handle.shutdown();
    for r in readers {
        let _ = r.join();
    }
    let _ = std::fs::remove_dir_all(&store_root);
}
