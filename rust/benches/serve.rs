//! `icecloud serve` load generator: requests/sec cold vs cached.
//!
//! Starts an in-process server on an ephemeral port and drives it with
//! the in-tree HTTP client (`server::http`).  "Cold" requests vary the
//! scenario seed every iteration, so every request forces a real
//! campaign replay; "cached" requests repeat one spec, so after the
//! first replay every response is served from the content-addressed
//! cache.  The subsystem's perf claim — cached throughput ≥ 100x cold
//! replay throughput — is printed as an explicit ratio at the end.
//!
//! Regenerate the committed baseline (BENCH_pr2.json) with:
//!   cargo bench --bench serve 2>/dev/null | grep BENCHJSON

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{ServeConfig, Server};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::bench::Bench;

fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

fn post_sweep(addr: &str, spec: &str) -> u16 {
    let resp = client_request(
        addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec.as_bytes(),
    )
    .expect("request");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    resp.status
}

fn main() {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 4,
        replay_threads: 2,
        cache_bytes: 64 << 20,
        base: tiny_base(),
    })
    .expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = server.spawn().expect("spawn");

    let mut b = Bench::new();

    // every iteration a fresh seed: full replay per request
    let mut seed = 0u64;
    b.run_throughput("serve/sweep-cold-replay", 1.0, "requests", || {
        seed += 1;
        post_sweep(&addr, &format!("[scenario.cold]\nseed = {seed}\n"))
    });

    // one spec repeated: replayed once, then pure cache traffic
    let hot_spec = "[scenario.hot]\nseed = 424242\n";
    post_sweep(&addr, hot_spec); // warm
    b.run_throughput("serve/sweep-cached", 1.0, "requests", || {
        post_sweep(&addr, hot_spec)
    });

    let results = b.results();
    let cold = results[0].throughput().unwrap_or(f64::NAN);
    let cached = results[1].throughput().unwrap_or(f64::NAN);
    println!(
        "\ncold {:.1} req/s, cached {:.1} req/s => cached/cold = {:.0}x \
         (target >= 100x)",
        cold,
        cached,
        cached / cold
    );

    b.finish();
    handle.shutdown();
}
