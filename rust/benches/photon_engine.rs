//! L1/L2 hot path: AOT photon artifact execution.
//!
//! Per-bunch latency and photon throughput for each compiled variant —
//! the real-compute cost the campaign's sampling pays, and the L1 number
//! recorded in EXPERIMENTS.md §Perf.  `photon/<variant>-bunch` runs the
//! batched engine single-threaded with the default lane sweep (the
//! campaign's default); `photon/<variant>-bunch-scalar-sweep` pins the
//! same plan to `SimdMode::Off` so the lane-sweep win is visible per
//! variant; the `-mt` twins run all cores (`ExecPlan::auto`) — results
//! are bit-identical across all of them, only wall time moves.  Skipped
//! (with a notice) when artifacts have not been built; the artifact-free
//! scalar-vs-batched comparison lives in `benches/sweep.rs`.

use icecloud::runtime::{build_inputs, ExecPlan, PhotonEngine, SimdMode};
use icecloud::util::bench::Bench;
use std::path::PathBuf;

fn main() {
    let dir = std::env::var("ICECLOUD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    let Ok(engine) = PhotonEngine::new(&dir) else {
        println!("photon_engine: artifacts not built; run `python -m compile.aot` from python/");
        return;
    };
    let mut b = Bench::new();

    for variant in ["small", "default", "large"] {
        let Ok(exe) = engine.compile(variant) else { continue };
        let photons = exe.meta.num_photons as f64;
        let mut seed = 0u32;
        b.run_throughput(
            &format!("photon/{variant}-bunch"),
            photons,
            "photons",
            || {
                seed = seed.wrapping_add(1);
                exe.run_seeded(seed).unwrap().detected()
            },
        );
        let mut seed = 0u32;
        b.run_throughput(
            &format!("photon/{variant}-bunch-scalar-sweep"),
            photons,
            "photons",
            || {
                seed = seed.wrapping_add(1);
                let inputs = build_inputs(&exe.meta, seed, true);
                exe.run_with_plan(
                    &inputs,
                    ExecPlan { simd: SimdMode::Off, ..ExecPlan::default() },
                )
                .unwrap()
                .detected()
            },
        );
        let mut seed = 0u32;
        b.run_throughput(
            &format!("photon/{variant}-bunch-mt"),
            photons,
            "photons",
            || {
                seed = seed.wrapping_add(1);
                let inputs = build_inputs(&exe.meta, seed, true);
                exe.run_with_plan(&inputs, ExecPlan::auto())
                    .unwrap()
                    .detected()
            },
        );
    }

    // compile cost (paid once per variant at campaign start)
    b.run("photon/compile-small", || engine.compile("small").unwrap());

    b.finish();
}
