//! One bench per paper figure/table: the cost of regenerating each.
//!
//! F1/F2/T1 share a campaign run; NAT and RAMP are separate scenarios.
//! Scaled-down scenarios keep `cargo bench` minutes-fast; the full-size
//! regeneration is `icecloud reproduce --all` (see EXPERIMENTS.md).

use icecloud::config::{CampaignConfig, OutageSpec, RampStep};
use icecloud::coordinator::{Campaign, CampaignResult};
use icecloud::experiments::{fig1, fig2, headline, nat, ramp};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::bench::Bench;

fn mini_campaign() -> CampaignResult {
    let mut c = CampaignConfig::default();
    c.duration_s = 2 * DAY;
    c.ramp = vec![
        RampStep { target: 40, hold_s: 6 * HOUR },
        RampStep { target: 120, hold_s: 60 * DAY },
    ];
    c.outage = Some(OutageSpec { at_s: DAY + 6 * HOUR, duration_s: 2 * HOUR });
    c.post_outage_target = 60;
    c.low_budget_resume_fraction = 1.1;
    c.onprem.slots = 100;
    c.generator.min_backlog = 300;
    Campaign::new(c).run()
}

fn main() {
    let mut b = Bench::new();

    b.run("figures/campaign-for-f1-f2-t1", mini_campaign);

    let result = mini_campaign();
    b.run("figures/fig1-extract+render", || {
        let f = fig1::extract(&result);
        (f.chart().len(), f.to_csv().len())
    });
    b.run("figures/fig2-extract+render", || {
        let f = fig2::extract(&result);
        (f.chart().len(), f.to_csv().len())
    });
    b.run("figures/headline-extract", || {
        headline::extract(&result).table().len()
    });

    b.run("figures/nat-sweep-2-points", || {
        nat::run_sweep(&[120, 300], 3 * HOUR, 24).len()
    });

    b.run("figures/ramp-validation", || ramp::run_validation(60, 1).len());

    b.finish();
}
