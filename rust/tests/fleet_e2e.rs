//! Fleet end-to-end: a coordinator and real worker threads speaking the
//! lease/heartbeat protocol over real HTTP connections.
//!
//! The headline property is the paper's reproducibility claim carried
//! into distributed execution: a sweep drained by remote workers — even
//! under worker churn (a worker dying mid-lease, exactly how a
//! preempted spot instance goes) — returns the *byte-identical* body a
//! single-process sweep of the same spec produces.  The coordinator
//! earns that by validating every returned row (sha256 of its own
//! re-rendering, plus sampled local re-replays) before admitting it
//! through the same content-addressed cache path local results use.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{
    FleetOptions, ServeConfig, Server, ServerHandle, WorkerOptions,
    WorkerReport,
};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Three scenarios: enough for one to be orphaned mid-lease while the
/// others drain, small enough to replay in test time.
const SPEC: &str =
    "[scenario.a]\n\n[scenario.b]\nseed = 9\n\n[scenario.c]\nbudget_usd = 40.0\n";
const SPEC_PAIR: &str = "[scenario.a]\n\n[scenario.b]\nseed = 4\n";
const SPEC_ONE: &str = "[scenario.solo]\nseed = 11\n";

fn tiny_base() -> CampaignConfig {
    let mut base = CampaignConfig::default();
    base.duration_s = 2 * HOUR;
    base.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    base.outage = None;
    base.onprem.slots = 8;
    base.generator.min_backlog = 30;
    base
}

fn start_server(fleet: FleetOptions) -> (ServerHandle, String) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        fleet,
        base: tiny_base(),
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    (server.spawn().unwrap(), addr)
}

/// Sub-second lease timing so churn recovery happens in test time.
fn fast_fleet(spot_check_rate: f64) -> FleetOptions {
    FleetOptions {
        lease_ttl: Duration::from_millis(2_000),
        heartbeat_every: Duration::from_millis(250),
        spot_check_rate,
    }
}

fn spawn_worker(
    addr: &str,
    id: &str,
    fail_after_leases: Option<u64>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<Result<WorkerReport, String>> {
    let opts = WorkerOptions {
        coordinator: addr.to_string(),
        worker_id: id.to_string(),
        slots: 1,
        poll: Duration::from_millis(25),
        fail_after_leases,
        engine_simd: icecloud::runtime::SimdMode::default(),
    };
    std::thread::spawn(move || {
        icecloud::server::fleet::run_worker(&opts, &stop)
    })
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..2_000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("timed out waiting for {what}");
}

/// One-process reference bytes for a spec: a fleet-less server computes
/// the sweep on its local replay pool.
fn local_baseline(spec: &str) -> Vec<u8> {
    let (handle, addr) = start_server(FleetOptions::default());
    let resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec.as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    handle.shutdown();
    resp.body
}

/// The flagship fault-injection scenario: three workers, one killed
/// mid-lease (stops heartbeating, drops its connection, never
/// completes).  The sweep must still finish, the orphaned unit must be
/// requeued onto a survivor, and the final body must be byte-identical
/// to a single-process sweep of the same spec.
#[test]
fn fleet_sweep_is_byte_identical_under_worker_churn() {
    let want = local_baseline(SPEC);

    let (handle, addr) = start_server(fast_fleet(0.0));
    let stop = Arc::new(AtomicBool::new(false));
    // the doomed worker vanishes right after its first lease grant —
    // no heartbeat, no completion, no goodbye
    let doomed = spawn_worker(&addr, "doomed", Some(1), Arc::clone(&stop));
    wait_until("the doomed worker to register", || {
        handle.state().fleet.stats().workers_registered >= 1
    });

    // the sweep blocks its connection until every row is home
    let sweep_addr = addr.clone();
    let sweep = std::thread::spawn(move || {
        client_request(
            &sweep_addr,
            "POST",
            "/sweep",
            Some("application/toml"),
            SPEC.as_bytes(),
        )
        .unwrap()
    });
    wait_until("the doomed worker to take a lease", || {
        handle.state().fleet.stats().leases_granted >= 1
    });
    let report = doomed.join().unwrap().unwrap();
    assert!(report.leases >= 1);
    assert_eq!(report.completed, 0, "the doomed worker completes nothing");

    // two healthy workers drain the rest, including the orphaned unit
    // once its lease expires
    let w1 = spawn_worker(&addr, "w1", None, Arc::clone(&stop));
    let w2 = spawn_worker(&addr, "w2", None, Arc::clone(&stop));

    let got = sweep.join().unwrap();
    assert_eq!(got.status, 200, "{}", got.body_str());
    assert_eq!(
        got.body, want,
        "fleet-computed sweep must be byte-identical to the local one"
    );

    let stats = handle.state().fleet.stats();
    assert!(
        stats.leases_expired >= 1,
        "the orphaned lease must expire and requeue: {stats:?}"
    );
    assert!(
        stats.leases_completed >= 1,
        "survivors must complete units: {stats:?}"
    );
    assert_eq!(stats.units_pending, 0, "{stats:?}");
    assert_eq!(stats.leases_outstanding, 0, "{stats:?}");

    // the churn is visible on /metrics
    let m = client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    assert_eq!(m.status, 200);
    let text = m.body_str();
    let expired: u64 = text
        .lines()
        .find(|l| l.starts_with("icecloud_fleet_leases_expired_total "))
        .and_then(|l| l.rsplit(' ').next())
        .expect("expired counter exposed")
        .parse()
        .expect("expired counter is a number");
    assert!(expired >= 1, "{text}");

    stop.store(true, Ordering::Relaxed);
    w1.join().unwrap().unwrap();
    w2.join().unwrap().unwrap();
    handle.shutdown();
}

/// With `spot_check_rate = 1.0` every fleet completion is re-replayed
/// locally before admission; honest workers pass every check and the
/// body still matches the single-process baseline.
#[test]
fn spot_checks_admit_honest_workers() {
    let want = local_baseline(SPEC_PAIR);

    let (handle, addr) = start_server(FleetOptions {
        lease_ttl: Duration::from_secs(10),
        heartbeat_every: Duration::from_millis(250),
        spot_check_rate: 1.0,
    });
    let stop = Arc::new(AtomicBool::new(false));
    let w = spawn_worker(&addr, "honest", None, Arc::clone(&stop));
    wait_until("the worker to register", || {
        handle.state().fleet.stats().workers_registered >= 1
    });

    let got = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        SPEC_PAIR.as_bytes(),
    )
    .unwrap();
    assert_eq!(got.status, 200, "{}", got.body_str());
    assert_eq!(got.body, want);

    let stats = handle.state().fleet.stats();
    assert!(stats.spot_checks_pass >= 1, "{stats:?}");
    assert_eq!(stats.spot_checks_fail, 0, "{stats:?}");
    assert_eq!(stats.leases_rejected, 0, "{stats:?}");

    stop.store(true, Ordering::Relaxed);
    w.join().unwrap().unwrap();
    handle.shutdown();
}

/// A byzantine "worker" speaking raw HTTP returns a corrupt completion:
/// the coordinator rejects it with 400, requeues the unit, and an
/// honest worker finishes the sweep with the correct bytes.
#[test]
fn corrupted_completion_is_rejected_and_the_unit_recovers() {
    let want = local_baseline(SPEC_ONE);

    let (handle, addr) = start_server(FleetOptions {
        lease_ttl: Duration::from_secs(10),
        heartbeat_every: Duration::from_secs(2),
        spot_check_rate: 0.0,
    });
    let resp = client_request(
        &addr,
        "POST",
        "/fleet/register",
        Some("application/json"),
        br#"{"worker_id": "evil", "slots": 1}"#,
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str());

    let sweep_addr = addr.clone();
    let sweep = std::thread::spawn(move || {
        client_request(
            &sweep_addr,
            "POST",
            "/sweep",
            Some("application/toml"),
            SPEC_ONE.as_bytes(),
        )
        .unwrap()
    });

    // poll for the grant by hand
    let mut lease_id = None;
    for _ in 0..2_000 {
        let resp = client_request(
            &addr,
            "POST",
            "/fleet/lease",
            Some("application/json"),
            br#"{"worker_id": "evil"}"#,
        )
        .unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let doc = json::parse(resp.body_str().trim()).unwrap();
        if let Some(id) = doc.get("lease_id").and_then(json::Json::as_u64) {
            assert_eq!(doc.get("name").unwrap().as_str(), Some("solo"));
            lease_id = Some(id);
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let lease_id = lease_id.expect("evil worker got a lease");

    // a row that does not decode, under a sha that matches nothing
    let corrupt = format!(
        "{{\"lease_id\": {lease_id}, \"sha256\": \"{}\", \"row\": {{}}}}",
        "0".repeat(64)
    );
    let resp = client_request(
        &addr,
        "POST",
        "/fleet/complete",
        Some("application/json"),
        corrupt.as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body_str());
    let stats = handle.state().fleet.stats();
    assert!(stats.leases_rejected >= 1, "{stats:?}");
    assert_eq!(stats.leases_completed, 0, "{stats:?}");

    // an honest worker picks up the requeued unit
    let stop = Arc::new(AtomicBool::new(false));
    let w = spawn_worker(&addr, "honest", None, Arc::clone(&stop));
    let got = sweep.join().unwrap();
    assert_eq!(got.status, 200, "{}", got.body_str());
    assert_eq!(
        got.body, want,
        "corruption must never reach the result cache"
    );

    stop.store(true, Ordering::Relaxed);
    w.join().unwrap().unwrap();
    handle.shutdown();
}

/// Adversarial routing, over real connections: unknown query params,
/// wrong methods, oversized bodies and unknown lease ids all bounce
/// with the right status — and none of them perturb the fleet table.
#[test]
fn fleet_routes_are_strict_over_http() {
    let (handle, addr) = start_server(FleetOptions::default());
    let before = handle.state().fleet.stats();

    // unknown query parameter: 400, not a silent no-op
    let resp = client_request(
        &addr,
        "POST",
        "/fleet/lease?priority=high",
        Some("application/json"),
        br#"{"worker_id": "w"}"#,
    )
    .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body_str());

    // wrong method: 405 + Allow
    let resp =
        client_request(&addr, "GET", "/fleet/heartbeat", None, b"").unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("POST"));

    // oversized body: 413 straight from the HTTP layer
    let huge = vec![b'a'; 2 * 1024 * 1024];
    let resp = client_request(
        &addr,
        "POST",
        "/fleet/complete",
        Some("application/json"),
        &huge,
    )
    .unwrap();
    assert_eq!(resp.status, 413);

    // heartbeat for a lease that never existed: 404, table untouched
    let resp = client_request(
        &addr,
        "POST",
        "/fleet/heartbeat",
        Some("application/json"),
        br#"{"lease_id": 7}"#,
    )
    .unwrap();
    assert_eq!(resp.status, 404, "{}", resp.body_str());

    assert_eq!(
        handle.state().fleet.stats(),
        before,
        "adversarial requests must not perturb the fleet table"
    );
    handle.shutdown();
}
