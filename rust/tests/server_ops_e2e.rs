//! End-to-end tests for the ops read plane — `/timeseries`, `/dash`,
//! `/dash.json`, the `/events` routing contract — and the
//! `[server] jobs_keep` age-out bound, all over real sockets.
//!
//! The routing table mirrors the fleet-protocol strictness tests: a
//! query string on an ops endpoint is a 400, a wrong method is a 405
//! with `Allow`, an unknown series is a 404, and an oversized body is
//! a 413 — a caller bug is never a silent no-op.  The age-out
//! regression pins the two halves of the `jobs_keep` contract: an
//! aged-out job id stops answering on `/jobs/<id>` while its result
//! keeps serving from the cache under `/results/<key>`.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::{client_request, MAX_BODY_BYTES};
use icecloud::server::{ServeConfig, Server, ServerHandle};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::json::{self, Json};
use std::time::{Duration, Instant};

/// A campaign small enough that a replay takes milliseconds.
fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 2 * HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

fn start_server(cfg: ServeConfig) -> (ServerHandle, String) {
    let server = Server::bind(cfg).expect("bind ephemeral port");
    let handle = server.spawn().expect("spawn server");
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn default_server() -> (ServerHandle, String) {
    start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 16,
        job_runners: 2,
        store_dir: None,
        base: tiny_base(),
        ..ServeConfig::default()
    })
}

fn parse_body(body: &[u8]) -> Json {
    json::parse(std::str::from_utf8(body).expect("utf-8 body").trim())
        .expect("json body")
}

/// Poll `/jobs/<id>` until `done` (panics on `failed` or timeout).
fn wait_done(addr: &str, id: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let resp =
            client_request(addr, "GET", &format!("/jobs/{id}"), None, b"")
                .expect("poll");
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let status = parse_body(&resp.body)
            .get("status")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        match status.as_str() {
            "done" => return,
            "failed" => panic!("job {id} failed"),
            _ => {
                assert!(Instant::now() < deadline, "job {id} timed out");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// The strict-routing table for the ops plane, over the wire.
#[test]
fn ops_endpoints_enforce_method_query_and_size_contracts() {
    let (handle, addr) = default_server();

    // wrong method: 405 with the Allow header
    for (method, path) in [
        ("POST", "/events"),
        ("DELETE", "/events"),
        ("POST", "/timeseries"),
        ("DELETE", "/timeseries/jobs.queued"),
        ("POST", "/dash"),
        ("PUT", "/dash.json"),
    ] {
        let resp = client_request(&addr, method, path, None, b"").unwrap();
        assert_eq!(resp.status, 405, "{method} {path}");
        assert_eq!(resp.header("allow"), Some("GET"), "{method} {path}");
    }

    // query strings are a hard error, not a silent no-op
    for path in [
        "/events?from=3",
        "/timeseries?limit=2",
        "/timeseries/jobs.queued?points=5",
        "/dash?theme=light",
        "/dash.json?pretty=1",
    ] {
        let resp = client_request(&addr, "GET", path, None, b"").unwrap();
        assert_eq!(resp.status, 400, "GET {path}");
    }

    // unknown series: 404
    let resp = client_request(&addr, "GET", "/timeseries/nope", None, b"")
        .unwrap();
    assert_eq!(resp.status, 404);

    // an oversized body is refused with 413 before routing even runs
    let big = vec![b'x'; MAX_BODY_BYTES + 1];
    let resp = client_request(&addr, "GET", "/dash", None, &big).unwrap();
    assert_eq!(resp.status, 413);

    handle.shutdown();
}

/// The sampler feeds `/timeseries` and `/dash` from server startup:
/// the index lists the burn-down series, a single series returns its
/// points, the board renders SVG and its JSON twin agrees.
#[test]
fn timeseries_and_dash_serve_the_sampled_burn_down() {
    let (handle, addr) = start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 4,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 16,
        job_runners: 1,
        store_dir: None,
        sample_every_s: 1,
        base: tiny_base(),
        ..ServeConfig::default()
    });

    // the sampler records once at startup, so the index is never empty
    // for long; poll briefly to absorb thread-start jitter
    let deadline = Instant::now() + Duration::from_secs(10);
    let doc = loop {
        let resp =
            client_request(&addr, "GET", "/timeseries", None, b"").unwrap();
        assert_eq!(resp.status, 200);
        let doc = parse_body(&resp.body);
        if doc.get("count").unwrap().as_u64().unwrap() > 0 {
            break doc;
        }
        assert!(Instant::now() < deadline, "sampler never ticked");
        std::thread::sleep(Duration::from_millis(20));
    };
    let series = doc.get("series").unwrap().as_arr().unwrap();
    let names: Vec<&str> = series
        .iter()
        .map(|s| s.get("name").unwrap().as_str().unwrap())
        .collect();
    for expected in [
        "jobs.queued",
        "jobs.running",
        "goodput.hours",
        "wasted.hours",
        "events.published",
    ] {
        assert!(names.contains(&expected), "{names:?} missing {expected}");
    }

    let one = client_request(
        &addr,
        "GET",
        "/timeseries/jobs.queued",
        None,
        b"",
    )
    .unwrap();
    assert_eq!(one.status, 200);
    let doc = parse_body(&one.body);
    assert!(doc.get("samples").unwrap().as_u64().unwrap() >= 1);
    assert!(
        !doc.get("points").unwrap().as_arr().unwrap().is_empty(),
        "a sampled series returns points"
    );

    let svg = client_request(&addr, "GET", "/dash", None, b"").unwrap();
    assert_eq!(svg.status, 200);
    assert_eq!(svg.header("content-type"), Some("image/svg+xml"));
    let body = svg.body_str();
    assert!(body.starts_with("<svg "), "{body}");
    assert!(body.contains("jobs.queued"), "{body}");

    let twin =
        client_request(&addr, "GET", "/dash.json", None, b"").unwrap();
    assert_eq!(twin.status, 200);
    let doc = parse_body(&twin.body);
    assert!(
        !doc.get("series").unwrap().as_arr().unwrap().is_empty(),
        "the JSON twin carries the same series"
    );

    // the bus gauges are on /metrics whether or not anyone subscribes
    let metrics =
        client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    let text = metrics.body_str();
    assert!(text.contains("icecloud_events_published_total"), "{text}");
    assert!(text.contains("icecloud_events_dropped_total 0"), "{text}");
    assert!(text.contains("icecloud_events_subscribers 0"), "{text}");

    handle.shutdown();
}

/// The `jobs_keep` age-out contract: finish more jobs than the bound
/// keeps, and the oldest ids 404 on `/jobs/<id>` while their results
/// still serve from the cache under `/results/<key>`.
#[test]
fn aged_out_jobs_404_while_their_results_still_serve() {
    let (handle, addr) = start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 4,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 16,
        job_runners: 1,
        store_dir: None,
        jobs_keep: 2,
        base: tiny_base(),
        ..ServeConfig::default()
    });

    let mut ids = Vec::new();
    for seed in 0..4u32 {
        let spec = format!("[scenario.age]\nseed = {seed}\n");
        let resp = client_request(
            &addr,
            "POST",
            "/sweep?mode=async",
            Some("application/toml"),
            spec.as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 202, "{}", resp.body_str());
        let id = parse_body(&resp.body)
            .get("job_id")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        wait_done(&addr, &id);
        ids.push(id);
    }

    // the two oldest records aged out of the job table...
    for old in &ids[..2] {
        let resp = client_request(
            &addr,
            "GET",
            &format!("/jobs/{old}"),
            None,
            b"",
        )
        .unwrap();
        assert_eq!(resp.status, 404, "job {old} should have aged out");
    }
    // ...the two newest are still tracked...
    for kept in &ids[2..] {
        let resp = client_request(
            &addr,
            "GET",
            &format!("/jobs/{kept}"),
            None,
            b"",
        )
        .unwrap();
        assert_eq!(resp.status, 200, "job {kept} should survive");
    }
    let listing = client_request(&addr, "GET", "/jobs", None, b"").unwrap();
    assert_eq!(
        parse_body(&listing.body).get("count").unwrap().as_u64(),
        Some(2),
        "the listing holds exactly jobs_keep finished records"
    );

    // ...and every result, aged out or not, still serves by key
    for id in &ids {
        let resp = client_request(
            &addr,
            "GET",
            &format!("/results/{id}"),
            None,
            b"",
        )
        .unwrap();
        assert_eq!(resp.status, 200, "result {id} must outlive the job");
        assert_eq!(
            parse_body(&resp.body).get("key").unwrap().as_str(),
            Some(id.as_str())
        );
    }

    handle.shutdown();
}
