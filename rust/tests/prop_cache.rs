//! Property-based tests over the two-tier result cache, driven by the
//! in-tree mini-proptest harness (`util::proptest`).
//!
//! Invariants pinned across random op sequences (puts, gets, memory
//! flushes):
//!
//! * the memory tier never exceeds its byte budget (except for the
//!   deliberate single-oversized-entry carve-out);
//! * get-after-put coherence between tiers: a body that went in comes
//!   back bit-identical, whichever tier serves it;
//! * with a disk tier, eviction from memory never loses an entry —
//!   every key ever put remains retrievable forever;
//! * memory-tier byte accounting equals the sum of resident bodies.

use icecloud::server::cache::{Outcome, ResultCache};
use icecloud::server::store::DiskStore;
use icecloud::util::proptest::{ensure, forall, shrink_vec};
use icecloud::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

fn scratch() -> PathBuf {
    std::env::temp_dir().join(format!(
        "icecloud-prop-cache-{}-{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Keys draw from a small space so sequences revisit them; the body is
/// a pure function of the key (the production invariant: one content
/// address names one byte string forever).
fn key(i: u8) -> String {
    format!("{i:064x}")
}

fn body(i: u8) -> Vec<u8> {
    let len = 40 + (i as usize * 37) % 300;
    (0..len).map(|j| i.wrapping_add(j as u8)).collect()
}

#[derive(Debug, Clone)]
enum CacheOp {
    /// `get_or_compute` of key `i` (computes `body(i)` on miss).
    Put(u8),
    /// Tier-aware `lookup` of key `i`.
    Get(u8),
    /// Drop the whole memory tier (models pressure / restarts).
    ClearMem,
}

fn gen_ops(rng: &mut Rng) -> Vec<CacheOp> {
    let n = 10 + rng.below(50) as usize;
    (0..n)
        .map(|_| match rng.below(8) {
            0 => CacheOp::ClearMem,
            1 | 2 | 3 => CacheOp::Put(rng.below(8) as u8),
            _ => CacheOp::Get(rng.below(8) as u8),
        })
        .collect()
}

const BUDGET: usize = 600;

/// Drive one op sequence against a cache, checking the invariants
/// after every op.  `inserted` tracks the model: every key that has
/// ever been put.
fn drive(
    cache: &ResultCache,
    ops: &[CacheOp],
    has_disk: bool,
) -> Result<(), String> {
    let mut inserted: Vec<u8> = Vec::new();
    for op in ops {
        match op {
            CacheOp::Put(i) => {
                let (r, _) = cache
                    .get_or_compute(&key(*i), || Ok(body(*i)));
                let served = r.map_err(|e| format!("put failed: {e}"))?;
                ensure(
                    served.as_slice() == body(*i).as_slice(),
                    format!("put {i} served wrong bytes"),
                )?;
                if !inserted.contains(i) {
                    inserted.push(*i);
                }
            }
            CacheOp::Get(i) => match cache.lookup(&key(*i)) {
                Some((served, outcome)) => {
                    ensure(
                        inserted.contains(i),
                        format!("phantom key {i} served"),
                    )?;
                    ensure(
                        served.as_slice() == body(*i).as_slice(),
                        format!("get {i} served wrong bytes"),
                    )?;
                    ensure(
                        outcome != Outcome::Miss,
                        "lookup never computes".to_string(),
                    )?;
                }
                None => {
                    // without disk, eviction may lose entries; with
                    // disk, nothing ever disappears
                    ensure(
                        !(has_disk && inserted.contains(i)),
                        format!("disk tier lost key {i}"),
                    )?;
                }
            },
            CacheOp::ClearMem => cache.clear_memory(),
        }
        let (entries, bytes) = cache.stats();
        ensure(
            bytes <= BUDGET || entries == 1,
            format!(
                "memory tier over budget: {bytes} bytes in {entries} \
                 entries (budget {BUDGET})"
            ),
        )?;
    }
    // terminal coherence: with a disk tier every inserted key must
    // still serve its exact bytes, memory evictions notwithstanding
    if has_disk {
        for i in &inserted {
            let (served, _) = cache
                .lookup(&key(*i))
                .ok_or_else(|| format!("final lookup lost key {i}"))?;
            ensure(
                served.as_slice() == body(*i).as_slice(),
                format!("final lookup of {i} served wrong bytes"),
            )?;
        }
    }
    Ok(())
}

#[test]
fn prop_two_tier_cache_invariants() {
    forall(
        "two-tier-cache",
        0xCAC4E,
        25,
        gen_ops,
        shrink_vec,
        |ops| {
            let root = scratch();
            let disk = DiskStore::open(&root)
                .map_err(|e| format!("open store: {e}"))?;
            let cache = ResultCache::with_disk(BUDGET, Some(disk));
            let result = drive(&cache, ops, true);
            // nothing in this workload is corrupt, so nothing may have
            // been quarantined, and the disk index must cover exactly
            // the distinct keys ever put
            let verdict = result.and_then(|()| {
                let reopened = DiskStore::open(&root)
                    .map_err(|e| format!("reopen store: {e}"))?;
                let puts: std::collections::HashSet<u8> = ops
                    .iter()
                    .filter_map(|op| match op {
                        CacheOp::Put(i) => Some(*i),
                        _ => None,
                    })
                    .collect();
                ensure(
                    reopened.stats().0 == puts.len(),
                    format!(
                        "reopened store has {} entries for {} puts",
                        reopened.stats().0,
                        puts.len()
                    ),
                )?;
                ensure(
                    reopened.quarantined() == 0,
                    "clean workload must not quarantine".to_string(),
                )
            });
            let _ = std::fs::remove_dir_all(&root);
            verdict
        },
    );
}

#[test]
fn prop_memory_only_cache_invariants() {
    forall(
        "memory-only-cache",
        0xCAC4F,
        25,
        gen_ops,
        shrink_vec,
        |ops| {
            let cache = ResultCache::new(BUDGET);
            drive(&cache, ops, false)
        },
    );
}
