//! Golden byte-stability proof for the canonical serialization layer.
//!
//! The server's content-addressed result cache (DESIGN.md §13) and the
//! fleet lease protocol both assume `canonical_json` bytes are stable
//! across releases: a byte change silently orphans every cached result
//! and splits coordinator/worker replays.  This test pins the exact
//! compact canonical bytes of pinned configs — and the sweep cache key
//! derived from them — against a fixture generated *independently* by
//! `tools/golden_canonical_gen.py` (a Python mirror of the serializer,
//! so a bug cannot hide on both sides of the comparison).
//!
//! If this test fails you changed the canonical form.  That is only
//! ever correct as a deliberate, versioned act:
//!   1. bump the `v` tag in `CampaignConfig::canonical_json`,
//!   2. regenerate: `python3 tools/golden_canonical_gen.py`,
//!   3. say so in the PR description.
//! Never hand-edit `tests/golden/canonical_v2.json` to make CI green.

use icecloud::config::CampaignConfig;
use icecloud::server::cache::sweep_key;
use icecloud::sweep::parse_spec;
use icecloud::util::json;

const FIXTURE: &str = include_str!("golden/canonical_v2.json");

/// The full scenario-override surface, as pinned in the fixture's
/// `scenario_full` (kept in sync with `scenario_full()` in the
/// generator script).
const FULL_SPEC: &str = r#"
[scenario.bare]

[scenario.full]
seed = 7
duration_days = 2.5
budget_usd = 29000.0
preempt_multiplier = 4.0
keepalive_s = 300
nat_idle_timeout_s = 120
outage_at_days = 1.5
outage_duration_hours = 6.0
ramp_targets = [100, 200]
ramp_hold_days = [1.0, 0.5]
onprem_slots = 10
policy = "risk-aware"
checkpoint_every_s = 900
checkpoint_resume_overhead_s = 30
gpu_slots_per_instance = 4
checkpoint_size_gb = 2.5
checkpoint_transfer_mbps = 500.0
"#;

fn fixture(key: &str) -> String {
    let doc = json::parse(FIXTURE).expect("fixture is valid JSON");
    doc.get(key)
        .and_then(|v| v.as_str())
        .unwrap_or_else(|| panic!("fixture missing key '{key}'"))
        .to_string()
}

fn assert_golden(what: &str, actual: &str, expected: &str) {
    assert_eq!(
        actual, expected,
        "\ncanonical bytes changed for {what}.\n\
         This invalidates every cached sweep result and splits \
         coordinator/worker replays.\n\
         If intentional: bump the canonical `v` tag in \
         CampaignConfig::canonical_json, regenerate the fixture with \
         `python3 tools/golden_canonical_gen.py`, and call the bump \
         out in the PR.\n  actual:   {actual}\n  expected: {expected}"
    );
}

#[test]
fn default_campaign_bytes_are_pinned() {
    let actual =
        CampaignConfig::default().canonical_json().to_string_compact();
    assert_golden(
        "CampaignConfig::default()",
        &actual,
        &fixture("campaign_default"),
    );
}

#[test]
fn default_campaign_omits_the_pr10_knobs() {
    // Registering a knob must never move pre-existing cache keys: the
    // three PR-10 knobs serialize only when off their defaults.
    let bytes =
        CampaignConfig::default().canonical_json().to_string_compact();
    for key in [
        "gpu_slots_per_instance",
        "checkpoint_size_gb",
        "checkpoint_transfer_mbps",
    ] {
        assert!(
            !bytes.contains(key),
            "default canonical form must omit '{key}': {bytes}"
        );
    }
}

#[test]
fn off_default_new_knobs_bytes_are_pinned() {
    let mut c = CampaignConfig::default();
    c.gpu_slots_per_instance = 4;
    c.checkpoint_size_gb = 2.5;
    c.checkpoint_transfer_mbps = 500.0;
    let actual = c.canonical_json().to_string_compact();
    assert_golden(
        "CampaignConfig with PR-10 knobs off-default",
        &actual,
        &fixture("campaign_new_knobs"),
    );
}

#[test]
fn scenario_bytes_are_pinned_through_the_spec_parser() {
    let mut base = CampaignConfig::default();
    let scenarios =
        parse_spec(FULL_SPEC, &mut base).expect("golden spec parses");
    assert_eq!(scenarios.len(), 2, "bare + full, name-sorted");
    assert_golden(
        "ScenarioConfig 'bare' (no overrides)",
        &scenarios[0].canonical_json().to_string_compact(),
        &fixture("scenario_bare"),
    );
    assert_golden(
        "ScenarioConfig 'full' (every override set)",
        &scenarios[1].canonical_json().to_string_compact(),
        &fixture("scenario_full"),
    );
}

#[test]
fn sweep_cache_key_is_pinned() {
    let mut base = CampaignConfig::default();
    let scenarios = parse_spec("[scenario.bare]\n", &mut base)
        .expect("bare spec parses");
    let actual = sweep_key(&base, &scenarios);
    assert_golden(
        "sweep_key(default base, [bare])",
        &actual,
        &fixture("sweep_key_default_bare"),
    );
}

#[test]
fn canonical_round_trips_from_golden_bytes() {
    // from_canonical_json over the pinned bytes reproduces the pinned
    // bytes — including the absent-means-default exception for the
    // three omitted PR-10 knobs.
    for key in ["campaign_default", "campaign_new_knobs"] {
        let bytes = fixture(key);
        let doc = json::parse(&bytes).expect("golden bytes parse");
        let c = CampaignConfig::from_canonical_json(&doc)
            .unwrap_or_else(|e| panic!("{key} round-trip: {e}"));
        assert_eq!(
            c.canonical_json().to_string_compact(),
            bytes,
            "{key} must survive canonical -> config -> canonical"
        );
    }
}
