//! Property-based tests over coordinator, pool and fleet invariants,
//! driven by the in-tree mini-proptest harness (`util::proptest`).

use icecloud::cloud::{providers, CloudSim, RegionId};
use icecloud::condor::job::{gpu_job_ad, gpu_requirements};
use icecloud::condor::negotiator::negotiate;
use icecloud::condor::startd::{SlotId, Startd};
use icecloud::condor::{CondorPool, Schedd};
use icecloud::config::{PolicyMode, ProviderWeights};
use icecloud::coordinator::distribute;
use icecloud::net::NatProfile;
use icecloud::sim::MINUTE;
use icecloud::util::proptest::{ensure, forall, no_shrink, shrink_vec};
use icecloud::util::rng::Rng;

// ---- fleet invariants -------------------------------------------------------

/// Random operator scripts: (region, target) changes interleaved with time.
#[derive(Debug, Clone)]
enum Op {
    SetTarget(u32, u32),
    Advance(u64),
    ZeroAll,
}

fn gen_ops(rng: &mut Rng) -> Vec<Op> {
    let n = 5 + rng.below(40) as usize;
    (0..n)
        .map(|_| match rng.below(5) {
            0 => Op::ZeroAll,
            1 | 2 => Op::SetTarget(rng.below(20) as u32, rng.below(300) as u32),
            _ => Op::Advance(1 + rng.below(60)),
        })
        .collect()
}

#[test]
fn prop_fleet_invariants_under_random_operators() {
    forall(
        "fleet-invariants",
        0xF1EE7,
        40,
        gen_ops,
        shrink_vec,
        |ops| {
            let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
            let mut now = 0u64;
            for op in ops {
                match op {
                    Op::SetTarget(r, t) => {
                        let r = (*r as usize % fleet.num_regions()) as u32;
                        fleet.set_target(RegionId(r), *t);
                    }
                    Op::ZeroAll => fleet.zero_all_targets(),
                    Op::Advance(ticks) => {
                        for _ in 0..*ticks {
                            now += MINUTE;
                            fleet.tick(now, MINUTE);
                        }
                    }
                }
            }
            fleet.check_invariants(now).map_err(|e| e)?;
            // after one settling tick, reconcile must have terminated any
            // surplus: live never exceeds the group targets
            now += MINUTE;
            fleet.tick(now, MINUTE);
            fleet.check_invariants(now)?;
            let counts = fleet.counts();
            ensure(
                counts.live() <= counts.target,
                format!("live {} above target {}", counts.live(), counts.target),
            )
        },
    );
}

// ---- policy invariants ------------------------------------------------------

#[test]
fn prop_policy_distribution_sums_and_bounds() {
    forall(
        "policy-sums",
        0xD157,
        200,
        |rng| {
            (
                rng.below(5000) as u32,
                rng.f64(),
                rng.f64(),
                rng.f64(),
            )
        },
        no_shrink,
        |(total, a, b, c)| {
            // degenerate all-zero weights handled separately
            if *a + *b + *c == 0.0 {
                return Ok(());
            }
            let fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
            let mode = PolicyMode::Fixed(ProviderWeights {
                aws: *a,
                gcp: *b,
                azure: *c,
            });
            let t = distribute(*total, &fleet, &mode, None);
            let sum: u32 = t.values().sum();
            ensure(
                sum.abs_diff(*total) <= 2,
                format!("sum {sum} != total {total} (rounding > 2)"),
            )?;
            ensure(
                t.len() == fleet.num_regions(),
                "every region must get an entry",
            )
        },
    );
}

// ---- schedd state machine ---------------------------------------------------

#[derive(Debug, Clone)]
enum JobOp {
    Submit,
    StartLowest,
    CompleteAny,
    InterruptAny,
}

fn gen_job_ops(rng: &mut Rng) -> Vec<JobOp> {
    let n = 5 + rng.below(120) as usize;
    (0..n)
        .map(|_| match rng.below(4) {
            0 => JobOp::Submit,
            1 => JobOp::StartLowest,
            2 => JobOp::CompleteAny,
            _ => JobOp::InterruptAny,
        })
        .collect()
}

#[test]
fn prop_schedd_state_machine() {
    forall(
        "schedd-state-machine",
        0x5EDD,
        60,
        gen_job_ops,
        shrink_vec,
        |ops| {
            let mut s = Schedd::new();
            let mut now = 0u64;
            let mut next_slot = 0u64;
            for op in ops {
                now += 60;
                match op {
                    JobOp::Submit => {
                        s.submit(
                            "icecube",
                            3600,
                            1e12,
                            10,
                            gpu_job_ad("icecube", 8192),
                            gpu_requirements(),
                            now,
                        );
                    }
                    JobOp::StartLowest => {
                        let first = s.idle_jobs().next();
                        if let Some(id) = first {
                            let slot =
                                SlotId::Cloud(icecloud::cloud::InstanceId(next_slot));
                            next_slot += 1;
                            s.start(id, slot, now);
                        }
                    }
                    JobOp::CompleteAny => {
                        let running: Vec<_> = s
                            .jobs()
                            .iter()
                            .filter(|j| {
                                j.state == icecloud::condor::JobState::Running
                            })
                            .map(|j| j.id)
                            .collect();
                        if let Some(id) = running.first() {
                            s.complete(*id, now);
                        }
                    }
                    JobOp::InterruptAny => {
                        let running: Vec<_> = s
                            .jobs()
                            .iter()
                            .filter(|j| {
                                j.state == icecloud::condor::JobState::Running
                            })
                            .map(|j| j.id)
                            .collect();
                        if let Some(id) = running.last() {
                            s.interrupt(*id, now);
                        }
                    }
                }
                s.check_invariants()?;
            }
            // accounting identities
            let total_good: u64 = s.jobs().iter().map(|j| j.goodput_s).sum();
            let total_bad: u64 = s.jobs().iter().map(|j| j.badput_s).sum();
            ensure(total_good == s.stats.goodput_s, "goodput sum mismatch")?;
            ensure(total_bad == s.stats.badput_s, "badput sum mismatch")
        },
    );
}

// ---- negotiation invariants ---------------------------------------------------

#[test]
fn prop_negotiation_no_double_booking() {
    forall(
        "negotiate-no-double-booking",
        0xBEEF,
        40,
        |rng| (1 + rng.below(60), 1 + rng.below(120), rng.below(4)),
        no_shrink,
        |(slots, jobs, clusters)| {
            let startds: icecloud::util::fxhash::FxHashMap<SlotId, Startd> = (0..*slots)
                .map(|i| {
                    let slot = SlotId::Cloud(icecloud::cloud::InstanceId(i));
                    (
                        slot,
                        Startd::new(
                            slot,
                            "cloud",
                            Some(icecloud::cloud::Provider::Azure),
                            "azure/eastus",
                            NatProfile::permissive("prop"),
                            60,
                            0,
                        ),
                    )
                })
                .collect();
            let mut schedd = Schedd::new();
            for i in 0..*jobs {
                let mem = 4096 + 1024 * (i % (clusters + 1)) as i64;
                schedd.submit(
                    "icecube",
                    3600,
                    1e12,
                    10,
                    gpu_job_ad("icecube", mem),
                    gpu_requirements(),
                    0,
                );
            }
            let r = negotiate(&schedd, &startds, startds.keys().copied(), usize::MAX);
            // no slot or job appears twice
            let mut slots_seen = std::collections::HashSet::new();
            let mut jobs_seen = std::collections::HashSet::new();
            for (job, slot) in &r.matches {
                ensure(slots_seen.insert(*slot), format!("slot {slot} reused"))?;
                ensure(jobs_seen.insert(*job), format!("job {job} reused"))?;
            }
            // match count bounded by both sides
            ensure(
                r.matches.len() <= (*slots).min(*jobs) as usize,
                "more matches than possible",
            )?;
            // all matchable jobs matched when slots are plentiful
            if slots >= jobs {
                ensure(
                    r.matches.len() == *jobs as usize,
                    format!("{} of {jobs} matched with {slots} slots",
                            r.matches.len()),
                )?;
            }
            Ok(())
        },
    );
}

// ---- pool invariants under random churn ----------------------------------------

#[derive(Debug, Clone)]
enum PoolOp {
    AddWorker,
    KillWorker,
    SubmitJobs(u8),
    Advance(u8),
    OutageToggle,
}

fn gen_pool_ops(rng: &mut Rng) -> Vec<PoolOp> {
    let n = 10 + rng.below(60) as usize;
    (0..n)
        .map(|_| match rng.below(8) {
            0 => PoolOp::OutageToggle,
            1 | 2 => PoolOp::AddWorker,
            3 => PoolOp::KillWorker,
            4 => PoolOp::SubmitJobs(1 + rng.below(10) as u8),
            _ => PoolOp::Advance(1 + rng.below(30) as u8),
        })
        .collect()
}

#[test]
fn prop_pool_invariants_under_churn() {
    forall(
        "pool-invariants",
        0xB001_0A11,
        30,
        gen_pool_ops,
        shrink_vec,
        |ops| {
            let mut pool = CondorPool::new();
            let mut now = 0u64;
            let mut next_worker = 0u64;
            let mut live: Vec<SlotId> = Vec::new();
            let mut outage = false;
            let mut events = Vec::new();
            for op in ops {
                match op {
                    PoolOp::AddWorker => {
                        let slot = SlotId::Cloud(icecloud::cloud::InstanceId(
                            next_worker,
                        ));
                        next_worker += 1;
                        pool.add_startd(
                            Startd::new(
                                slot,
                                "cloud",
                                Some(icecloud::cloud::Provider::Gcp),
                                "gcp/us-central1",
                                NatProfile::permissive("prop"),
                                60,
                                now,
                            ),
                            now,
                        );
                        live.push(slot);
                    }
                    PoolOp::KillWorker => {
                        if let Some(slot) = live.pop() {
                            pool.remove_startd(slot, now, &mut events);
                        }
                    }
                    PoolOp::SubmitJobs(n) => {
                        for _ in 0..*n {
                            pool.schedd.submit(
                                "icecube",
                                1800,
                                1e12,
                                10,
                                gpu_job_ad("icecube", 8192),
                                gpu_requirements(),
                                now,
                            );
                        }
                    }
                    PoolOp::Advance(ticks) => {
                        for _ in 0..*ticks {
                            now += MINUTE;
                            pool.tick(now, &mut events);
                        }
                    }
                    PoolOp::OutageToggle => {
                        if outage {
                            pool.end_outage();
                        } else {
                            pool.begin_outage(now, &mut events);
                        }
                        outage = !outage;
                    }
                }
                pool.check_invariants()?;
            }
            Ok(())
        },
    );
}

// ---- classad robustness ----------------------------------------------------------

#[test]
fn prop_classad_parser_never_panics() {
    forall(
        "classad-no-panic",
        0xC1A55,
        300,
        |rng| {
            let tokens = [
                "&&", "||", "==", "<=", "(", ")", "1", "2.5", "x", "MY.",
                "TARGET.", "\"s\"", "!", "-", "+", "*", "/", "true",
                "undefined", " ",
            ];
            let n = rng.below(12) as usize;
            (0..n)
                .map(|_| *rng.choose(&tokens).unwrap())
                .collect::<Vec<_>>()
                .join("")
        },
        no_shrink,
        |src| {
            // parse may fail, but must never panic; eval likewise
            if let Ok(expr) = icecloud::condor::classad::parse(src) {
                let ad = icecloud::condor::Ad::new();
                let _ = expr.eval(&ad, None);
            }
            Ok(())
        },
    );
}
