//! End-to-end tests for the live operations event stream (`GET
//! /events`) over real sockets.
//!
//! Each test binds its own server on an ephemeral port and speaks raw
//! SSE to it: a hand-rolled client reads `id:`/`event:`/`data:` frames
//! off the wire exactly as `curl -N` would.  Pinned here are the bus
//! contract's observable halves: a live subscriber sees every job
//! transition in order exactly once; a reconnect with `Last-Event-ID`
//! replays only what was missed; and a deliberately slow subscriber
//! receives an explicit `gap` event while the sweep data plane keeps
//! producing bytes identical to a subscriber-less server.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{EventKind, ServeConfig, Server, ServerHandle};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// A campaign small enough that a replay takes milliseconds.
fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 2 * HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

fn start_server(cfg: ServeConfig) -> (ServerHandle, String) {
    let server = Server::bind(cfg).expect("bind ephemeral port");
    let handle = server.spawn().expect("spawn server");
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn default_server() -> (ServerHandle, String) {
    start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 16,
        job_runners: 2,
        store_dir: None,
        base: tiny_base(),
        ..ServeConfig::default()
    })
}

fn parse_body(body: &[u8]) -> Json {
    json::parse(std::str::from_utf8(body).expect("utf-8 body").trim())
        .expect("json body")
}

/// Block until the server's bus shows exactly `n` open subscriptions —
/// the only way to know an SSE connection's handler has subscribed.
fn wait_subscribers(handle: &ServerHandle, n: u64) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while handle.state().events.subscriber_count() != n {
        assert!(
            Instant::now() < deadline,
            "never reached {n} subscribers"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// One SSE frame as read off the wire.
#[derive(Debug, Clone)]
struct Frame {
    id: Option<u64>,
    event: Option<String>,
    data: Option<String>,
    /// `true` for comment-only frames (heartbeats).
    comment: bool,
}

/// A hand-rolled SSE client over one raw TCP connection.
struct SseStream {
    reader: BufReader<TcpStream>,
}

impl SseStream {
    /// Connect, send the GET and consume the response head; panics
    /// unless the server commits to `text/event-stream`.
    fn connect(addr: &str, last_event_id: Option<u64>) -> SseStream {
        let stream = TcpStream::connect(addr).expect("connect sse");
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut stream = stream;
        let mut head =
            format!("GET /events HTTP/1.1\r\nHost: {addr}\r\n");
        if let Some(id) = last_event_id {
            head.push_str(&format!("Last-Event-ID: {id}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes()).expect("send sse request");
        let mut reader = BufReader::new(stream);
        let mut status = String::new();
        reader.read_line(&mut status).expect("read status line");
        assert!(status.starts_with("HTTP/1.1 200"), "{status}");
        let mut saw_event_stream = false;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read head");
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if line.to_ascii_lowercase().starts_with("content-type:") {
                assert!(line.contains("text/event-stream"), "{line}");
                saw_event_stream = true;
            }
        }
        assert!(saw_event_stream, "head must advertise the stream");
        SseStream { reader }
    }

    /// Read one frame (a heartbeat comment counts as a frame).
    fn next_frame(&mut self) -> Frame {
        let mut frame = Frame {
            id: None,
            event: None,
            data: None,
            comment: false,
        };
        let mut saw_any = false;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).expect("sse read");
            assert!(n > 0, "stream closed mid-frame");
            let line = line.trim_end_matches('\n');
            if line.is_empty() {
                if saw_any {
                    return frame;
                }
                continue;
            }
            saw_any = true;
            if let Some(rest) = line.strip_prefix("id: ") {
                frame.id = Some(rest.parse().expect("numeric id"));
            } else if let Some(rest) = line.strip_prefix("event: ") {
                frame.event = Some(rest.to_string());
            } else if let Some(rest) = line.strip_prefix("data: ") {
                frame.data = Some(rest.to_string());
            } else if line.starts_with(':') {
                frame.comment = true;
            } else {
                panic!("unexpected SSE line: {line:?}");
            }
        }
    }

    /// Read frames until `n` real (non-heartbeat) events arrive.
    fn next_events(&mut self, n: usize) -> Vec<Frame> {
        let mut out = Vec::new();
        while out.len() < n {
            let f = self.next_frame();
            if !f.comment {
                out.push(f);
            }
        }
        out
    }
}

/// A live subscriber sees the async job lifecycle as an exact ordered
/// sequence — queued, running, done — each exactly once, with strictly
/// increasing sequence numbers, and heartbeats once the bus goes quiet.
#[test]
fn live_stream_reports_job_lifecycle_in_order_exactly_once() {
    let (handle, addr) = default_server();
    let mut sse = SseStream::connect(&addr, None);
    wait_subscribers(&handle, 1);

    let resp = client_request(
        &addr,
        "POST",
        "/sweep?mode=async",
        Some("application/toml"),
        b"[scenario.a]\nseed = 5\n",
    )
    .unwrap();
    assert_eq!(resp.status, 202, "{}", resp.body_str());
    let id = parse_body(&resp.body)
        .get("job_id")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();

    let events = sse.next_events(3);
    let names: Vec<&str> =
        events.iter().map(|f| f.event.as_deref().unwrap()).collect();
    assert_eq!(names, ["job.queued", "job.running", "job.done"]);
    let seqs: Vec<u64> = events.iter().map(|f| f.id.unwrap()).collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");
    for f in &events {
        let data = json::parse(f.data.as_deref().unwrap()).unwrap();
        assert_eq!(
            data.get("id").unwrap().as_str(),
            Some(id.as_str()),
            "every transition names the job"
        );
    }
    assert_eq!(
        events[0]
            .data
            .as_deref()
            .map(|d| json::parse(d).unwrap())
            .unwrap()
            .get("scenarios")
            .unwrap()
            .as_u64(),
        Some(1)
    );

    // the bus is quiet now: the next frame is a heartbeat comment, not
    // a replayed or duplicated transition
    let beat = sse.next_frame();
    assert!(beat.comment, "expected a heartbeat, got {beat:?}");

    drop(sse);
    handle.shutdown();
}

/// Kill a subscriber, let events flow past it, reconnect with the last
/// seen id as `Last-Event-ID`: the stream resumes with exactly the
/// missed events and no gap (the ring still holds them).
#[test]
fn last_event_id_resume_replays_only_the_missed_events() {
    let (handle, addr) = default_server();

    let mut sse = SseStream::connect(&addr, None);
    wait_subscribers(&handle, 1);
    let first = client_request(
        &addr,
        "POST",
        "/sweep?mode=async",
        Some("application/toml"),
        b"[scenario.one]\nseed = 1\n",
    )
    .unwrap();
    assert_eq!(first.status, 202);
    let seen = sse.next_events(3);
    let last_seen = seen.last().unwrap().id.unwrap();
    drop(sse); // hang up mid-stream

    // a second job's transitions flow with no subscriber attached
    let second = client_request(
        &addr,
        "POST",
        "/sweep?mode=async",
        Some("application/toml"),
        b"[scenario.two]\nseed = 2\n",
    )
    .unwrap();
    assert_eq!(second.status, 202);
    let id2 = parse_body(&second.body)
        .get("job_id")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    // poll until done so all three transitions are in the ring
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let poll = client_request(
            &addr,
            "GET",
            &format!("/jobs/{id2}"),
            None,
            b"",
        )
        .unwrap();
        let status = parse_body(&poll.body)
            .get("status")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        assert_ne!(status, "failed");
        if status == "done" {
            break;
        }
        assert!(Instant::now() < deadline, "job 2 never finished");
        std::thread::sleep(Duration::from_millis(10));
    }

    // reconnect where we left off: exactly the missed three, no gap
    let mut resumed = SseStream::connect(&addr, Some(last_seen));
    let replay = resumed.next_events(3);
    let names: Vec<&str> =
        replay.iter().map(|f| f.event.as_deref().unwrap()).collect();
    assert_eq!(names, ["job.queued", "job.running", "job.done"]);
    assert_eq!(replay[0].id.unwrap(), last_seen + 1, "no hole, no gap");
    for f in &replay {
        assert_ne!(f.event.as_deref(), Some("gap"));
        let data = json::parse(f.data.as_deref().unwrap()).unwrap();
        assert_eq!(data.get("id").unwrap().as_str(), Some(id2.as_str()));
    }
    assert_eq!(handle.state().events.dropped_total(), 0);

    drop(resumed);
    handle.shutdown();
}

/// The slow-reader contract, end to end: a subscriber that stops
/// reading while a burst far larger than the ring flows past it gets
/// an explicit `gap` event on catch-up — and the sweep data plane,
/// running on the same server throughout, still produces bytes
/// identical to a server with no subscribers at all.
#[test]
fn slow_subscriber_gets_a_gap_while_sweep_bytes_stay_identical() {
    let spec = b"[scenario.base]\nseed = 42\n";

    // subscriber-less baseline bytes from a fresh server
    let (baseline_handle, baseline_addr) = default_server();
    let baseline = client_request(
        &baseline_addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(baseline.status, 200);
    baseline_handle.shutdown();

    // tiny ring so a burst is guaranteed to lap a stalled reader
    let (handle, addr) = start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 16,
        job_runners: 2,
        store_dir: None,
        events_ring: 64,
        base: tiny_base(),
        ..ServeConfig::default()
    });
    let mut sse = SseStream::connect(&addr, None);
    wait_subscribers(&handle, 1);

    // with the subscriber attached but about to stall, the sweep path
    // still matches the subscriber-less baseline byte for byte
    let with_sub = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(with_sub.status, 200);
    assert_eq!(
        with_sub.body, baseline.body,
        "subscribers must not perturb sweep results"
    );

    // the client now stops reading; flood the bus with far more events
    // than socket buffers and a 64-slot ring can hold between them
    let bus = &handle.state().events;
    for i in 0..200_000u64 {
        bus.publish(EventKind::JobDone { id: format!("synthetic-{i}") });
    }

    // resume reading: somewhere after the buffered backlog the handler
    // catches up, notices this reader's cursor fell off the ring, and
    // emits the explicit gap frame
    let mut gap = None;
    let mut idle_streak = 0u32;
    for _ in 0..400_000 {
        let f = sse.next_frame();
        if f.event.as_deref() == Some("gap") {
            gap = Some(f);
            break;
        }
        // consecutive heartbeats mean the backlog fully drained: the
        // stream went idle without ever admitting to the lost events
        idle_streak = if f.comment { idle_streak + 1 } else { 0 };
        assert!(idle_streak < 5, "stream drained without a gap event");
    }
    let gap = gap.expect("a lapped subscriber must see a gap event");
    let dropped = json::parse(gap.data.as_deref().unwrap())
        .unwrap()
        .get("dropped")
        .unwrap()
        .as_u64()
        .unwrap();
    assert!(dropped >= 1, "gap reports how many events were lost");
    assert!(handle.state().events.dropped_total() >= dropped);
    // the frame after the gap is the oldest retained event: contiguous
    // with the gap's own id, so Last-Event-ID resume stays exact
    let next = sse.next_events(1).remove(0);
    assert_eq!(next.id.unwrap(), gap.id.unwrap() + 1);

    // and the data plane never noticed: identical bytes, served again
    let after = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(after.status, 200);
    assert_eq!(after.body, baseline.body);

    drop(sse);
    handle.shutdown();
}
