//! `icecloud diff` acceptance: two sweep result files (as written by
//! the sweep harness, or as served from `/results/<key>`) join by
//! scenario name and render per-column deltas — plus the RFC-4180
//! round trip for hostile scenario names that motivated the CSV
//! quoting fix.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::experiments::{diff, sweep as sweep_exp};
use icecloud::sim::{DAY, HOUR};
use icecloud::sweep::{parse_spec, run_matrix};

fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

/// Run a 2-scenario sweep and return its `sweep.json` bytes, exactly as
/// `icecloud sweep --out` writes them.
fn sweep_json(budget: f64) -> String {
    let spec = format!(
        "[scenario.baseline]\n\n[scenario.tuned]\nbudget_usd = {budget}\n"
    );
    let mut base = tiny_base();
    let scenarios = parse_spec(&spec, &mut base).unwrap();
    let rows = run_matrix(&base, &scenarios, 2);
    sweep_exp::to_json(&rows).to_string_pretty()
}

#[test]
fn diff_of_two_sweep_files_renders_per_column_deltas() {
    let a = sweep_json(200.0);
    let b = sweep_json(400.0);

    let ra = diff::parse_rows(&a).unwrap();
    let rb = diff::parse_rows(&b).unwrap();
    assert_eq!(ra.len(), 2);
    let d = diff::diff(&ra, &rb);
    assert_eq!(d.rows.len(), 2);
    assert!(d.only_a.is_empty() && d.only_b.is_empty());

    // 'baseline' is untouched by the budget change; 'tuned' differs in
    // budget_usd by exactly the spec delta
    let tuned = d.rows.iter().find(|r| r.name == "tuned").unwrap();
    assert_eq!(tuned.cells["budget_usd"], (200.0, 400.0));
    let baseline = d.rows.iter().find(|r| r.name == "baseline").unwrap();
    for (col, (av, bv)) in &baseline.cells {
        assert!(
            av == bv || (av.is_nan() && bv.is_nan()),
            "baseline column {col} changed: {av} vs {bv}"
        );
    }

    // the three renderings all carry the delta
    let txt = diff::render(&d);
    assert!(txt.contains("tuned"), "{txt}");
    assert!(txt.contains("budget_usd"), "{txt}");
    assert!(txt.contains("200 -> 400"), "{txt}");
    let csv = diff::to_csv(&d);
    assert!(csv.lines().any(|l| l.starts_with("tuned,budget_usd,200,400,200,100")), "{csv}");
    let j = diff::to_json(&d);
    assert_eq!(j.get("joined").unwrap().as_u64(), Some(2));

    // a diff against itself is all-quiet
    let same = diff::diff(&ra, &ra);
    let txt = diff::render(&same);
    assert!(txt.contains("2 scenarios joined, 0 changed"), "{txt}");
}

#[test]
fn results_body_shape_diffs_like_sweep_json() {
    // the server's /results/<key> body wraps the same rows in
    // {"key": ..., "rows": [...]} — both shapes must parse
    let a = sweep_json(200.0);
    let wrapped = format!("{{\"key\": \"deadbeef\", \"rows\": {a}}}");
    assert_eq!(
        diff::parse_rows(&a).unwrap(),
        diff::parse_rows(&wrapped).unwrap()
    );
}

/// Minimal RFC-4180 line splitter for the round-trip check: honours
/// quoted fields and doubled quotes.
fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut field = String::new();
    let mut chars = line.chars().peekable();
    let mut quoted = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if field.is_empty() && !quoted => quoted = true,
            '"' if quoted => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    quoted = false;
                }
            }
            ',' if !quoted => {
                out.push(std::mem::take(&mut field));
            }
            c => field.push(c),
        }
    }
    out.push(field);
    out
}

#[test]
fn hostile_scenario_names_round_trip_through_csv() {
    // a quoted TOML key makes names with commas legal; before the
    // quoting fix this row shifted every downstream column (names with
    // embedded quotes are covered by the csv_field unit tests)
    let spec = "[scenario.\"a,b\"]\nseed = 9\n\n[scenario.plain]\n";
    let mut base = tiny_base();
    let scenarios = parse_spec(spec, &mut base).unwrap();
    assert_eq!(scenarios[0].name, "a,b");
    let rows = run_matrix(&base, &scenarios, 1);
    let csv = sweep_exp::to_csv(&rows);
    let header = split_csv_line(csv.lines().next().unwrap());
    assert_eq!(header.len(), 23);
    for line in csv.lines().skip(1) {
        let fields = split_csv_line(line);
        assert_eq!(fields.len(), 23, "shifted row: {line}");
    }
    let hostile = split_csv_line(csv.lines().nth(1).unwrap());
    assert_eq!(hostile[0], "a,b", "name must round-trip exactly");
    assert_eq!(hostile[1], "9");
}
