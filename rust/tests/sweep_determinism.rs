//! Sweep determinism contract: the same matrix + seeds must produce
//! byte-identical summaries, and the worker-thread count must not change
//! any per-scenario result.  These properties make sweep output citable
//! (EXPERIMENTS.md records seeds next to numbers) and are what allows
//! the runner to scale across cores without a reproducibility tax.

use icecloud::config::{CampaignConfig, NatOverride, RampStep};
use icecloud::coordinator::ScenarioConfig;
use icecloud::experiments;
use icecloud::sim::{DAY, HOUR};
use icecloud::sweep;

fn small_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 12 * HOUR;
    c.ramp = vec![RampStep { target: 40, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 20;
    c.generator.min_backlog = 120;
    c
}

/// A compact matrix that still exercises every override axis.
fn small_matrix() -> Vec<ScenarioConfig> {
    let mut m = vec![ScenarioConfig::named("baseline")];

    let mut s = ScenarioConfig::named("budget-tight");
    s.budget_usd = Some(20.0);
    m.push(s);

    let mut s = ScenarioConfig::named("churn-x25");
    s.preempt_multiplier = Some(25.0);
    m.push(s);

    let mut s = ScenarioConfig::named("keepalive-300");
    s.keepalive_s = Some(300);
    m.push(s);

    let mut s = ScenarioConfig::named("no-nat-300");
    s.keepalive_s = Some(300);
    s.nat_override = Some(NatOverride::Disabled);
    m.push(s);

    let mut s = ScenarioConfig::named("other-seed");
    s.seed = Some(777);
    m.push(s);

    m
}

#[test]
fn same_matrix_twice_is_byte_identical() {
    let base = small_base();
    let matrix = small_matrix();
    let a = sweep::run_matrix(&base, &matrix, 3);
    let b = sweep::run_matrix(&base, &matrix, 3);
    assert_eq!(a, b, "summaries must replay identically");
    assert_eq!(
        experiments::sweep::render(&a),
        experiments::sweep::render(&b)
    );
    assert_eq!(
        experiments::sweep::to_csv(&a),
        experiments::sweep::to_csv(&b)
    );
}

#[test]
fn thread_count_does_not_change_results() {
    let base = small_base();
    let matrix = small_matrix();
    let sequential = sweep::run_matrix(&base, &matrix, 1);
    let parallel = sweep::run_matrix(&base, &matrix, 4);
    assert_eq!(sequential.len(), parallel.len());
    for (s, p) in sequential.iter().zip(parallel.iter()) {
        assert_eq!(s, p, "scenario '{}' diverged across thread counts", s.name);
    }
    assert_eq!(
        experiments::sweep::to_csv(&sequential),
        experiments::sweep::to_csv(&parallel)
    );
}

#[test]
fn scenario_overrides_change_outcomes_as_expected() {
    let base = small_base();
    let rows = sweep::run_matrix(&base, &small_matrix(), 4);
    let get = |name: &str| {
        rows.iter().find(|r| r.name == name).expect("scenario row")
    };
    let baseline = get("baseline");

    // the tuned keepalive never drops; the OSG default storms on Azure
    assert_eq!(baseline.nat_drops, 0);
    assert!(get("keepalive-300").nat_drops > 0);
    // ... unless the NAT itself has no idle expiry
    assert_eq!(get("no-nat-300").nat_drops, 0);

    // a $20 budget drains the fleet: strictly cheaper, less compute
    let tight = get("budget-tight");
    assert!(tight.cost_usd() < baseline.cost_usd());
    assert!(tight.gpu_days < baseline.gpu_days);

    // 25x churn hazard preempts far more often than the calm baseline
    assert!(
        get("churn-x25").preemptions > baseline.preemptions,
        "churn-x25 {} vs baseline {}",
        get("churn-x25").preemptions,
        baseline.preemptions
    );

    // a different seed is a different (but internally valid) history
    let other = get("other-seed");
    assert_eq!(other.seed, 777);
    assert!(other.completed > 0);
}

#[test]
fn builtin_matrix_names_are_stable() {
    // the default matrix is part of the CLI contract (docs refer to the
    // scenario names); keep additions append-only
    let names: Vec<String> = sweep::builtin_matrix()
        .into_iter()
        .map(|s| s.name)
        .collect();
    assert!(names.len() >= 8);
    for expected in [
        "baseline",
        "no-outage",
        "budget-half",
        "budget-quarter",
        "churn-x4",
        "churn-x10",
        "keepalive-300",
        "no-nat",
        "ramp-aggressive",
        "policy-adaptive",
    ] {
        assert!(
            names.iter().any(|n| n == expected),
            "builtin matrix lost scenario '{expected}'"
        );
    }
}
