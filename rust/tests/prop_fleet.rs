//! Property tests for the fleet lease table (`server::fleet`).
//!
//! Random register/lease/heartbeat/expire/complete sequences, with two
//! invariants checked after *every* operation:
//!
//!   1. No unit is ever granted to two live workers at once, and no
//!      unit is ever lost: at any instant the pending queue, the live
//!      leases, and the delivered result slots partition the sweep's
//!      unit set exactly.
//!   2. Lease conservation: `granted == completed + expired + rejected
//!      + outstanding` — the accounting identity `/metrics` exposes,
//!      so operators can audit fleet health from counters alone.
//!
//! A second property drives any prefix of churn to completion: after an
//! arbitrary op sequence, an honest drain loop always finishes the
//! sweep with every slot filled.

use icecloud::cloudbank::BudgetSnapshot;
use icecloud::config::CampaignConfig;
use icecloud::coordinator::ScenarioConfig;
use icecloud::server::fleet::{CompleteOutcome, FleetOptions, FleetTable};
use icecloud::server::fleet::SweepFlight;
use icecloud::sweep::{summary_to_wire, ScenarioSummary};
use icecloud::util::proptest::{ensure, forall, shrink_vec, PropResult};
use icecloud::util::sha256;
use std::sync::Arc;
use std::time::Duration;

/// How many scenario units each generated sweep carries.
const UNITS: usize = 5;
/// The worker pool the ops draw from.
const WORKERS: [&str; 3] = ["w0", "w1", "w2"];

/// One protocol operation.  Index arguments are taken modulo the live
/// set at execution time, so every generated sequence is executable.
#[derive(Clone, Debug)]
enum Op {
    /// Register (or re-register) worker `i % 3`.
    Register(u8),
    /// Worker `i % 3` asks for a lease (may be unknown → refused).
    Lease(u8),
    /// Heartbeat live lease `k`; with none live, heartbeat a bogus id.
    Heartbeat(u8),
    /// Force-expire live lease `k` (the missed-heartbeat path).
    Expire(u8),
    /// Honestly complete live lease `k`.
    Complete(u8),
}

fn gen_ops(r: &mut icecloud::util::rng::Rng) -> Vec<Op> {
    let len = r.below(40) as usize;
    (0..len)
        .map(|_| {
            let arg = r.below(6) as u8;
            match r.below(5) {
                0 => Op::Register(arg),
                1 => Op::Lease(arg),
                2 => Op::Heartbeat(arg),
                3 => Op::Expire(arg),
                _ => Op::Complete(arg),
            }
        })
        .collect()
}

/// A wall-clock-proof table: the TTL is so long that only explicit
/// `Expire` ops ever expire a lease, making the model deterministic.
fn table() -> FleetTable {
    FleetTable::new(FleetOptions {
        lease_ttl: Duration::from_secs(3_600),
        heartbeat_every: Duration::from_secs(1_200),
        spot_check_rate: 0.0,
    })
}

/// A syntactically valid summary row for `name`; completions built
/// from it pass the coordinator's sha + decode + name validation.
fn fake_row(name: &str) -> ScenarioSummary {
    ScenarioSummary {
        name: name.to_string(),
        seed: 7,
        duration_days: 0.25,
        snapshot: BudgetSnapshot {
            at: 900,
            budget_usd: 58_000.0,
            spent_usd: 12.5,
            aws_usd: 4.0,
            gcp_usd: 4.0,
            azure_usd: 4.5,
        },
        gpu_days: 1.5,
        eflop_hours: 0.002,
        cost_per_eflop_hour: 6_250.0,
        peak_gpus: 10.0,
        mean_gpus: 8.0,
        completed: 120,
        interrupted: 3,
        goodput_fraction: 0.97,
        nat_drops: 0,
        preemptions: 2,
        resumes: 2,
        goodput_hours: 36.0,
        wasted_hours: 1.0,
        expansion_factor: 1.1,
        alerts: 1,
    }
}

fn honest_complete(fleet: &FleetTable, lease_id: u64, name: &str) -> CompleteOutcome {
    let wire = summary_to_wire(&fake_row(name));
    let sha = sha256::hex_digest(wire.to_string_compact().as_bytes());
    fleet.complete(lease_id, &sha, &wire)
}

/// The two invariants, checked against the table's own introspection.
/// `live` is the model's view of outstanding (lease_id, unit name).
fn check_invariants(
    fleet: &FleetTable,
    flight: &SweepFlight,
    live: &[(u64, String)],
) -> PropResult {
    let s = fleet.stats();
    ensure(
        s.leases_granted
            == s.leases_completed
                + s.leases_expired
                + s.leases_rejected
                + s.leases_outstanding as u64,
        format!("lease conservation violated: {s:?}"),
    )?;
    ensure(
        s.leases_rejected == 0,
        format!("honest completions must never be rejected: {s:?}"),
    )?;
    ensure(
        s.leases_outstanding == live.len(),
        format!("outstanding {} != model {}", s.leases_outstanding, live.len()),
    )?;

    let leased = fleet.leased_unit_ids();
    let mut deduped = leased.clone();
    deduped.sort_unstable();
    deduped.dedup();
    ensure(
        deduped.len() == leased.len(),
        format!("unit granted to two live workers at once: {leased:?}"),
    )?;

    // pending ∪ leased ∪ delivered must partition the unit set exactly
    // (the first sweep on a fresh table numbers its units 0..UNITS, and
    // result slot i belongs to unit i)
    let pending = fleet.pending_unit_ids();
    let filled = flight.filled_slots();
    let mut all: Vec<u64> = pending
        .iter()
        .copied()
        .chain(leased.iter().copied())
        .chain(filled.iter().map(|&slot| slot as u64))
        .collect();
    all.sort_unstable();
    let expect: Vec<u64> = (0..UNITS as u64).collect();
    ensure(
        all == expect,
        format!(
            "units lost or duplicated: pending={pending:?} leased={leased:?} \
             delivered={filled:?}"
        ),
    )
}

/// Run one op sequence against a fresh table, checking invariants
/// after every step.  Returns the table, flight, and live-lease model
/// so callers can keep going (e.g. drain to completion).
fn run_ops(
    ops: &[Op],
) -> Result<(FleetTable, Arc<SweepFlight>, Vec<(u64, String)>), String> {
    let fleet = table();
    let base = CampaignConfig::default();
    let scenarios: Vec<ScenarioConfig> = (0..UNITS)
        .map(|i| ScenarioConfig::named(&format!("u{i}")))
        .collect();
    let flight = fleet.begin_sweep(&base, &scenarios);
    let mut live: Vec<(u64, String)> = Vec::new();

    for op in ops {
        match op {
            Op::Register(w) => {
                fleet.register(WORKERS[*w as usize % WORKERS.len()], 1);
            }
            Op::Lease(w) => {
                let wid = WORKERS[*w as usize % WORKERS.len()];
                match fleet.lease(wid) {
                    // unknown workers are refused, registered ones may
                    // idle if nothing is pending — both are fine
                    Err(_) | Ok(None) => {}
                    Ok(Some(grant)) => {
                        live.push((grant.lease_id, grant.name.clone()));
                    }
                }
            }
            Op::Heartbeat(k) => {
                if live.is_empty() {
                    ensure(
                        fleet.heartbeat(u64::MAX).is_none(),
                        "bogus lease id must not heartbeat",
                    )?;
                } else {
                    let id = live[*k as usize % live.len()].0;
                    ensure(
                        fleet.heartbeat(id).is_some(),
                        format!("live lease {id} must accept a heartbeat"),
                    )?;
                }
            }
            Op::Expire(k) => {
                if !live.is_empty() {
                    let (id, _) = live.remove(*k as usize % live.len());
                    ensure(
                        fleet.expire_lease(id),
                        format!("live lease {id} must be expirable"),
                    )?;
                }
            }
            Op::Complete(k) => {
                if !live.is_empty() {
                    let (id, name) = live.remove(*k as usize % live.len());
                    let out = honest_complete(&fleet, id, &name);
                    ensure(
                        out == CompleteOutcome::Accepted,
                        format!("honest completion of {id} got {out:?}"),
                    )?;
                }
            }
        }
        check_invariants(&fleet, &flight, &live)?;
    }
    Ok((fleet, flight, live))
}

#[test]
fn random_op_sequences_never_lose_or_double_grant_units() {
    forall(
        "fleet op-sequence invariants",
        0xF1EE7,
        150,
        gen_ops,
        shrink_vec,
        |ops| run_ops(ops).map(|_| ()),
    );
}

/// After any churn prefix, an honest worker can always drain the sweep:
/// expire whatever is still outstanding, then lease/complete until every
/// result slot is filled.  Bounded iterations — a unit leaked by the
/// table would fail the final check rather than hang the test.
#[test]
fn any_churn_prefix_still_drains_to_completion() {
    forall(
        "fleet drains after churn",
        0xD12A1,
        80,
        gen_ops,
        shrink_vec,
        |ops| {
            let (fleet, flight, live) = run_ops(ops)?;
            for (id, _) in &live {
                ensure(fleet.expire_lease(*id), "outstanding lease expirable")?;
            }
            fleet.register("drainer", 1);
            for _ in 0..(2 * UNITS) {
                match fleet.lease("drainer")? {
                    None => break,
                    Some(grant) => {
                        let out = honest_complete(
                            &fleet,
                            grant.lease_id,
                            &grant.name,
                        );
                        ensure(
                            out == CompleteOutcome::Accepted,
                            format!("drain completion got {out:?}"),
                        )?;
                    }
                }
            }
            let filled = flight.filled_slots();
            ensure(
                filled.len() == UNITS,
                format!("sweep did not drain: delivered slots {filled:?}"),
            )?;
            let s = fleet.stats();
            ensure(
                s.leases_outstanding == 0 && s.units_pending == 0,
                format!("drained table not quiescent: {s:?}"),
            )
        },
    );
}
