//! Registry-driven round-trip property: every knob in
//! `config::registry::KNOBS` — current and future — is driven through
//! BOTH parse paths using its own registry-declared `sample` literal,
//! and the results must agree byte-for-byte:
//!
//!   scenario spec  --parse_spec-->  ScenarioConfig --apply--+
//!                                                           +--> same
//!   campaign TOML  --apply_toml------------------------------+    bytes
//!
//! then canonical_json -> from_canonical_json -> canonical_json must
//! reproduce the exact bytes (the fleet lease round-trip).
//!
//! Because the test iterates `KNOBS` itself, registering a new knob
//! automatically enrolls it here — there is no way to add a sweepable
//! knob that skips the round-trip proof.

use icecloud::config::registry::{Knob, KNOBS};
use icecloud::config::CampaignConfig;
use icecloud::sweep::parse_spec;
use icecloud::util::toml;

/// Knobs that only validate in the presence of a partner knob, with
/// the partner's (scenario key, sample) pair.
fn companions(k: &Knob) -> &'static [(&'static str, &'static str)] {
    match k.name {
        "outage_duration_hours" => &[("outage_at_days", "1.5")],
        "ramp_hold_days" => &[("ramp_targets", "[100, 200]")],
        "checkpoint_resume_overhead_s" => {
            &[("checkpoint_every_s", "900")]
        }
        _ => &[],
    }
}

/// All (knob, sample) pairs a single-knob case needs.
fn case_knobs(k: &Knob) -> Vec<(&'static Knob, &'static str)> {
    let mut v = vec![(k, k.sample)];
    for (name, sample) in companions(k) {
        let c = icecloud::config::registry::lookup(name)
            .unwrap_or_else(|| panic!("companion '{name}' registered"));
        v.push((c, *sample));
    }
    v
}

/// Render the case as a `[scenario.x]` sweep-spec table.
fn scenario_spec(knobs: &[(&'static Knob, &'static str)]) -> String {
    let mut s = String::from("[scenario.x]\n");
    for (k, sample) in knobs {
        s.push_str(&format!("{} = {}\n", k.name, sample));
    }
    s
}

/// Render the same case as nested campaign TOML via each knob's
/// registry-declared `toml_path` (top-level keys first, then one
/// `[table]` section per path head — the TOML subset has no dotted
/// keys).
fn campaign_toml(knobs: &[(&'static Knob, &'static str)]) -> String {
    let mut top = String::new();
    let mut tables: Vec<(&str, String)> = Vec::new();
    for (k, sample) in knobs {
        match k.toml_path {
            [key] => top.push_str(&format!("{key} = {sample}\n")),
            [table, key] => {
                let line = format!("{key} = {sample}\n");
                match tables.iter_mut().find(|(t, _)| t == table) {
                    Some((_, body)) => body.push_str(&line),
                    None => tables.push((table, line)),
                }
            }
            other => panic!("unexpected toml_path depth: {other:?}"),
        }
    }
    let mut s = top;
    for (table, body) in tables {
        s.push_str(&format!("[{table}]\n{body}"));
    }
    s
}

#[test]
fn every_knob_round_trips_through_both_parse_paths() {
    for k in KNOBS.iter() {
        let knobs = case_knobs(k);

        // Path 1: scenario spec -> ScenarioConfig -> apply to base.
        let spec = scenario_spec(&knobs);
        let mut base = CampaignConfig::default();
        let scenarios = parse_spec(&spec, &mut base)
            .unwrap_or_else(|e| panic!("knob '{}': spec {spec:?} must parse: {e}", k.name));
        assert_eq!(scenarios.len(), 1);
        let via_scenario = scenarios[0].apply(&base);

        // Path 2: the same values as nested campaign TOML.
        let toml_text = campaign_toml(&knobs);
        let doc = toml::parse(&toml_text).unwrap_or_else(|e| {
            panic!("knob '{}': TOML {toml_text:?} must parse: {e:?}", k.name)
        });
        let mut via_campaign = CampaignConfig::default();
        via_campaign.apply_toml(&doc).unwrap_or_else(|e| {
            panic!("knob '{}': apply_toml must accept {toml_text:?}: {e}", k.name)
        });

        let a = via_scenario.canonical_json().to_string_compact();
        let b = via_campaign.canonical_json().to_string_compact();
        assert_eq!(
            a, b,
            "knob '{}': scenario-spec and campaign-TOML paths \
             disagree\n  spec: {spec:?}\n  toml: {toml_text:?}",
            k.name
        );

        // Lease round-trip: canonical -> config -> canonical, exact.
        let parsed = icecloud::util::json::parse(&a).expect("canonical parses");
        let back = CampaignConfig::from_canonical_json(&parsed)
            .unwrap_or_else(|e| {
                panic!("knob '{}': from_canonical_json: {e}", k.name)
            });
        assert_eq!(
            back.canonical_json().to_string_compact(),
            a,
            "knob '{}': canonical form must round-trip byte-exactly",
            k.name
        );
    }
}

#[test]
fn every_sample_is_a_valid_grid_cell_where_eligible() {
    // A grid axis sweeps single values of the same TOML literal the
    // sample declares, so every grid-eligible sample must expand.
    for k in KNOBS.iter().filter(|k| k.grid_axis) {
        let mut spec = String::from("[grid]\n");
        spec.push_str(&format!("{} = [{}]\n", k.name, k.sample));
        for (name, sample) in companions(k) {
            spec.push_str(&format!("{name} = [{sample}]\n"));
        }
        let mut base = CampaignConfig::default();
        let cells = parse_spec(&spec, &mut base).unwrap_or_else(|e| {
            panic!("knob '{}': grid {spec:?} must expand: {e}", k.name)
        });
        assert_eq!(
            cells.len(),
            1,
            "knob '{}': one value per axis -> one cell",
            k.name
        );
    }
}
