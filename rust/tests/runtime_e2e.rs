//! End-to-end tests of the PJRT runtime path: campaign with real compute.
//!
//! These require artifacts (`python -m compile.aot`) and are skipped (pass trivially)
//! otherwise — the Makefile's `test` target always builds artifacts first.

use icecloud::config::{CampaignConfig, RampStep, RealComputeConfig};
use icecloud::coordinator::Campaign;
use icecloud::runtime::PhotonEngine;
use icecloud::sim::{DAY, HOUR};
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("meta.json").exists().then_some(dir)
}

#[test]
fn campaign_with_real_compute_executes_bunches() {
    let Some(dir) = artifact_dir() else { return };
    let engine = PhotonEngine::new(&dir).unwrap();
    let exe = engine.compile("small").unwrap();

    let mut cfg = CampaignConfig::default();
    cfg.duration_s = 12 * HOUR;
    cfg.ramp = vec![RampStep { target: 40, hold_s: 60 * DAY }];
    cfg.outage = None;
    cfg.onprem.slots = 20;
    cfg.generator.min_backlog = 150;
    // short jobs so completions accumulate fast
    cfg.generator.runtimes.median_s = 1200.0;
    cfg.generator.runtimes.min_s = 600;
    cfg.generator.runtimes.max_s = 2400;
    cfg.real_compute = Some(RealComputeConfig {
        variant: "small".into(),
        every_n_completions: 20,
    });

    let result = Campaign::with_engine(cfg, Some(exe)).run();
    let rc = result.real_compute;
    assert!(rc.bunches >= 5, "expected sampled executions, got {}", rc.bunches);
    assert_eq!(rc.photons, rc.bunches * 256);
    assert!(rc.wall_s > 0.0);
    assert!(rc.flops > 0.0);
    // job FLOP accounting used the artifact's estimate
    assert!(result.schedd_stats.flops_done > 0.0);
}

#[test]
fn engine_throughput_is_deterministic_per_seed() {
    let Some(dir) = artifact_dir() else { return };
    let engine = PhotonEngine::new(&dir).unwrap();
    let exe = engine.compile("small").unwrap();
    let a = exe.run_seeded(123).unwrap();
    let b = exe.run_seeded(123).unwrap();
    assert_eq!(a.hits, b.hits);
    assert_eq!(a.summary, b.summary);
}

#[test]
fn all_variants_compile_and_conserve_photons() {
    let Some(dir) = artifact_dir() else { return };
    let engine = PhotonEngine::new(&dir).unwrap();
    for v in ["small", "default", "large"] {
        let exe = engine.compile(v).unwrap();
        let r = exe.run_seeded(5).unwrap();
        let total = (r.summary[0] + r.summary[1] + r.summary[2]) as u64;
        assert_eq!(total, exe.meta.num_photons, "variant {v}");
        assert_eq!(r.hits.len(), exe.meta.num_doms as usize, "variant {v}");
        assert!(r.hits.iter().all(|h| *h >= 0.0 && h.fract() == 0.0));
    }
}

#[test]
fn detection_rate_scales_with_dom_count() {
    // more DOMs (default: 60 on one string vs small: 16) => more detections
    // per photon for the same ice. This checks the artifacts carry real,
    // distinct geometry, not copies of one module.
    let Some(dir) = artifact_dir() else { return };
    let engine = PhotonEngine::new(&dir).unwrap();
    let small = engine.compile("small").unwrap();
    let default = engine.compile("default").unwrap();
    let mut rate_small = 0.0;
    let mut rate_default = 0.0;
    for seed in 0..4 {
        rate_small += small.run_seeded(seed).unwrap().detected() as f64
            / small.meta.num_photons as f64;
        rate_default += default.run_seeded(seed).unwrap().detected() as f64
            / default.meta.num_photons as f64;
    }
    assert!(
        rate_default > rate_small * 0.8,
        "default rate {rate_default} vs small {rate_small}"
    );
}
