//! Grid-expansion acceptance: a 3-axis {4,4,4} `[grid]` spec expands to
//! exactly 64 uniquely-named scenarios, byte-identical across parses,
//! runs and thread counts, and is accepted over `POST /sweep` exactly
//! like an explicit `[scenario.<name>]` matrix — same parse path, same
//! content-addressed cache keys.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{ServeConfig, Server, ServerHandle};
use icecloud::sim::{DAY, HOUR};
use icecloud::sweep::{parse_spec, run_matrix};
use icecloud::util::json;

/// A campaign small enough that a replay takes milliseconds.
fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

/// The acceptance grid: 3 axes x {4,4,4} values = 64 scenarios.
const GRID_SPEC: &str = "\
[grid]
preempt_multiplier = [1.0, 2.0, 4.0, 10.0]
budget_usd = [14500.0, 29000.0, 58000.0, 116000.0]
keepalive_s = [60, 120, 240, 300]
";

#[test]
fn grid_4x4x4_expands_to_64_unique_scenarios() {
    let mut base = tiny_base();
    let scenarios = parse_spec(GRID_SPEC, &mut base).unwrap();
    assert_eq!(scenarios.len(), 64);
    let mut names: Vec<&str> =
        scenarios.iter().map(|s| s.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), 64, "synthesized names must be unique");
    // deterministic: a second parse yields the identical list
    let again = parse_spec(GRID_SPEC, &mut tiny_base()).unwrap();
    assert_eq!(scenarios, again);
    // sorted-axis names, last sorted axis varying fastest
    assert_eq!(
        scenarios[0].name,
        "budget_usd=14500/keepalive_s=60/preempt_multiplier=1"
    );
    assert_eq!(
        scenarios[63].name,
        "budget_usd=116000/keepalive_s=300/preempt_multiplier=10"
    );
    // and the axis values really land in the configs
    assert_eq!(scenarios[0].budget_usd, Some(14500.0));
    assert_eq!(scenarios[0].keepalive_s, Some(60));
    assert_eq!(scenarios[63].preempt_multiplier, Some(10.0));
}

#[test]
fn grid_sweep_rows_are_byte_identical_across_thread_counts() {
    let mut base = tiny_base();
    let scenarios = parse_spec(GRID_SPEC, &mut base).unwrap();
    let one = run_matrix(&base, &scenarios, 1);
    let three = run_matrix(&base, &scenarios, 3);
    assert_eq!(
        icecloud::experiments::sweep::to_json(&one).to_string_compact(),
        icecloud::experiments::sweep::to_json(&three).to_string_compact(),
        "row bytes must not depend on worker-thread count"
    );
}

#[test]
fn grid_composes_with_base_and_explicit_scenarios() {
    // [base] applies to the shared campaign exactly as for explicit
    // matrices, and [grid] cells coexist with [scenario.<name>] tables
    // (grid product first, explicit names after)
    let spec = "\
[base]
duration_days = 0.25

[grid]
keepalive_s = [60, 120]

[scenario.extra]
budget_usd = 1000.0
";
    let mut base = tiny_base();
    let scenarios = parse_spec(spec, &mut base).unwrap();
    assert_eq!(base.duration_s, 6 * HOUR);
    let names: Vec<&str> =
        scenarios.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(
        names,
        vec!["keepalive_s=60", "keepalive_s=120", "extra"]
    );
    assert_eq!(scenarios[2].budget_usd, Some(1000.0));

    // an explicit scenario colliding with a synthesized name is an
    // error, not a silent shadow (quoted TOML keys make this legal to
    // write)
    let collision = "\
[grid]
keepalive_s = [60, 120]

[scenario.\"keepalive_s=60\"]
budget_usd = 1000.0
";
    let err = parse_spec(collision, &mut tiny_base()).unwrap_err();
    assert!(err.contains("collides"), "err={err}");

    // a spec with neither [grid] nor [scenario.*] is rejected
    let err =
        parse_spec("[base]\nduration_days = 1.0", &mut tiny_base())
            .unwrap_err();
    assert!(err.contains("[grid]"), "err={err}");
}

#[test]
fn grid_spec_loads_from_file_like_the_cli() {
    // the same loader `icecloud sweep --matrix/--grid` uses
    let path = std::env::temp_dir()
        .join(format!("icecloud-grid-spec-{}.toml", std::process::id()));
    std::fs::write(&path, GRID_SPEC).unwrap();
    let mut base = tiny_base();
    let scenarios = icecloud::sweep::matrix::from_toml_file(
        path.to_str().unwrap(),
        &mut base,
    )
    .unwrap();
    assert_eq!(scenarios.len(), 64);
    let _ = std::fs::remove_file(&path);
}

/// The PR-10 axes: 1 x 2 x 2 x 3 = 12 scenarios over the two new
/// knob families (fractional-GPU slot carve-up and checkpoint
/// transfer cost), registered as ordinary registry entries.
const NEW_AXES_GRID: &str = "\
[grid]
checkpoint_every_s = [900]
checkpoint_size_gb = [0.5, 2.0]
checkpoint_transfer_mbps = [100.0, 1000.0]
gpu_slots_per_instance = [1, 2, 4]
";

#[test]
fn new_registry_axes_sweep_from_the_cli_grid_path() {
    // same loader `icecloud sweep --grid` uses
    let mut base = tiny_base();
    let scenarios = parse_spec(NEW_AXES_GRID, &mut base).unwrap();
    assert_eq!(scenarios.len(), 12);
    // sorted-axis names, last sorted axis varying fastest; `2.0`
    // labels as `2` (the JSON number writer collapses integral floats)
    assert_eq!(
        scenarios[0].name,
        "checkpoint_every_s=900/checkpoint_size_gb=0.5/\
         checkpoint_transfer_mbps=100/gpu_slots_per_instance=1"
    );
    assert_eq!(
        scenarios[11].name,
        "checkpoint_every_s=900/checkpoint_size_gb=2/\
         checkpoint_transfer_mbps=1000/gpu_slots_per_instance=4"
    );
    // the axis values really land in the scenario overrides
    assert_eq!(scenarios[0].checkpoint_size_gb, Some(0.5));
    assert_eq!(scenarios[0].checkpoint_transfer_mbps, Some(100.0));
    assert_eq!(scenarios[0].gpu_slots_per_instance, Some(1));
    assert_eq!(scenarios[11].gpu_slots_per_instance, Some(4));
    // and the cells replay: 12 rows, deterministic across threads
    let one = run_matrix(&base, &scenarios, 1);
    assert_eq!(one.len(), 12);
    let two = run_matrix(&base, &scenarios, 2);
    assert_eq!(
        icecloud::experiments::sweep::to_json(&one).to_string_compact(),
        icecloud::experiments::sweep::to_json(&two).to_string_compact(),
    );
}

fn start_server() -> (ServerHandle, String) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 4,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        base: tiny_base(),
        ..ServeConfig::default()
    })
    .expect("bind ephemeral port");
    let handle = server.spawn().expect("spawn server");
    let addr = handle.addr().to_string();
    (handle, addr)
}

#[test]
fn post_sweep_accepts_the_64_cell_grid() {
    let (handle, addr) = start_server();
    let resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        GRID_SPEC.as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let doc = json::parse(resp.body_str().trim()).unwrap();
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 64);
    assert_eq!(
        rows[0].get("name").unwrap().as_str(),
        Some("budget_usd=14500/keepalive_s=60/preempt_multiplier=1")
    );

    // the replay is content-addressed: the same grid body again is a
    // byte-identical response
    let again = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        GRID_SPEC.as_bytes(),
    )
    .unwrap();
    assert_eq!(again.status, 200);
    assert_eq!(again.body, resp.body);

    // a grid past the per-request limit (5 x 4 x 4 = 80 > 64): refused
    let over = "[grid]\nseed = [1, 2, 3, 4, 5]\n\
                keepalive_s = [60, 120, 240, 300]\n\
                preempt_multiplier = [1.0, 2.0, 4.0, 10.0]\n";
    let resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        over.as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body_str());

    handle.shutdown();
}

#[test]
fn post_sweep_accepts_the_new_registry_axes() {
    // acceptance: both PR-10 knob families sweep over a real socket
    // with no router or matrix changes — registering the knobs was
    // enough to make them part of the wire surface
    let (handle, addr) = start_server();
    let resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        NEW_AXES_GRID.as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let doc = json::parse(resp.body_str().trim()).unwrap();
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 12);
    assert_eq!(
        rows[0].get("name").unwrap().as_str(),
        Some(
            "checkpoint_every_s=900/checkpoint_size_gb=0.5/\
             checkpoint_transfer_mbps=100/gpu_slots_per_instance=1"
        )
    );

    // content-addressed like every other sweep: same body, same bytes
    let again = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        NEW_AXES_GRID.as_bytes(),
    )
    .unwrap();
    assert_eq!(again.status, 200);
    assert_eq!(again.body, resp.body);

    // invalid values for the new axes are 4xx'd by the shared
    // registry validators, not silently accepted
    let bad = "[grid]\ngpu_slots_per_instance = [0]\n";
    let resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        bad.as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body_str());
    assert!(
        resp.body_str().contains("gpu_slots_per_instance"),
        "{}",
        resp.body_str()
    );

    handle.shutdown();
}
