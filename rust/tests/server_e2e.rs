//! End-to-end tests for `icecloud serve` over real sockets.
//!
//! Each test binds its own server on an ephemeral 127.0.0.1 port and
//! talks to it with the in-tree HTTP client (`server::http`), so the
//! wire format, the router, the replay pool, and the content-addressed
//! cache are exercised exactly as a curl user would hit them.  The
//! headline property pinned here is the acceptance criterion for the
//! subsystem: N concurrent identical `POST /sweep` requests cause
//! exactly one underlying replay, every response is byte-identical, and
//! `/metrics` accounts for N-1 cache hits.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::{client_request, read_client_response};
use icecloud::server::{ServeConfig, Server, ServerHandle};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::json;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

/// A campaign small enough that a replay takes milliseconds.
fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 2 * HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

fn start_server() -> (ServerHandle, String) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        base: tiny_base(),
        ..ServeConfig::default()
    })
    .expect("bind ephemeral port");
    let handle = server.spawn().expect("spawn server");
    let addr = handle.addr().to_string();
    (handle, addr)
}

#[test]
fn routing_basics() {
    let (handle, addr) = start_server();

    let resp = client_request(&addr, "GET", "/healthz", None, b"").unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.body_str().contains("\"status\":\"ok\""));

    let resp = client_request(&addr, "GET", "/matrix", None, b"").unwrap();
    assert_eq!(resp.status, 200);
    let doc = json::parse(resp.body_str().trim()).unwrap();
    let scenarios = doc.get("scenarios").unwrap().as_arr().unwrap();
    assert!(scenarios.len() >= 8);
    assert!(resp.body_str().contains("baseline"));

    let resp = client_request(&addr, "GET", "/nope", None, b"").unwrap();
    assert_eq!(resp.status, 404);

    let resp = client_request(&addr, "POST", "/healthz", None, b"").unwrap();
    assert_eq!(resp.status, 405);

    let resp = client_request(&addr, "GET", "/sweep", None, b"").unwrap();
    assert_eq!(resp.status, 405);

    handle.shutdown();
}

#[test]
fn versioned_surface_over_the_wire() {
    let (handle, addr) = start_server();

    // /v1 aliases resolve to the same handlers
    let resp =
        client_request(&addr, "GET", "/v1/healthz", None, b"").unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.body_str().contains("\"status\":\"ok\""));
    assert_eq!(resp.header("x-api-version"), Some("1"));

    // the version header rides every response, errors included
    let resp = client_request(&addr, "GET", "/healthz", None, b"").unwrap();
    assert_eq!(resp.header("x-api-version"), Some("1"));
    let resp = client_request(&addr, "GET", "/nope", None, b"").unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(resp.header("x-api-version"), Some("1"));

    // bare /v1 and non-boundary lookalikes are not the mount
    let resp = client_request(&addr, "GET", "/v1", None, b"").unwrap();
    assert_eq!(resp.status, 404);
    let resp =
        client_request(&addr, "GET", "/v1healthz", None, b"").unwrap();
    assert_eq!(resp.status, 404);

    // canonical error shape over the wire
    let doc = json::parse(resp.body_str().trim()).unwrap();
    assert_eq!(doc.get("error").unwrap().as_str(), Some("not_found"));
    assert!(doc.get("detail").unwrap().as_str().is_some());

    handle.shutdown();
}

#[test]
fn sweep_toml_then_results_key_roundtrip() {
    let (handle, addr) = start_server();
    let spec = b"[scenario.a]\n\n[scenario.b]\nbudget_usd = 20.0\n";

    let first = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(first.status, 200, "{}", first.body_str());
    assert_eq!(first.header("x-cache"), Some("miss"));
    let doc = json::parse(first.body_str().trim()).unwrap();
    let key = doc.get("key").unwrap().as_str().unwrap().to_string();
    assert_eq!(key.len(), 64);
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].get("name").unwrap().as_str(), Some("a"));
    assert_eq!(rows[1].get("name").unwrap().as_str(), Some("b"));
    assert!(rows[0].get("cost_usd").unwrap().as_f64().unwrap() > 0.0);

    // cached replay: byte-identical body, hit header
    let second = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(second.status, 200);
    assert_eq!(second.header("x-cache"), Some("hit"));
    assert_eq!(second.body, first.body);

    // the content address serves the same bytes
    let by_key = client_request(
        &addr,
        "GET",
        &format!("/results/{key}"),
        None,
        b"",
    )
    .unwrap();
    assert_eq!(by_key.status, 200);
    assert_eq!(by_key.body, first.body);

    let missing =
        client_request(&addr, "GET", "/results/0123abcd", None, b"")
            .unwrap();
    assert_eq!(missing.status, 404);

    handle.shutdown();
}

#[test]
fn sweep_json_body_is_equivalent() {
    let (handle, addr) = start_server();
    let toml_resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        b"[scenario.x]\nseed = 5\n",
    )
    .unwrap();
    let json_resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/json"),
        br#"{"scenario": {"x": {"seed": 5}}}"#,
    )
    .unwrap();
    assert_eq!(toml_resp.status, 200, "{}", toml_resp.body_str());
    assert_eq!(json_resp.status, 200, "{}", json_resp.body_str());
    assert_eq!(
        toml_resp.body, json_resp.body,
        "one spec, two encodings, one content address"
    );
    // the second request must have been a cache hit: same resolved config
    assert_eq!(json_resp.header("x-cache"), Some("hit"));

    handle.shutdown();
}

#[test]
fn malformed_bodies_rejected() {
    let (handle, addr) = start_server();
    for body in [
        &b"this is not a spec = ="[..],
        &b"[scenario.a]\nnot_a_knob = 1\n"[..],
        &br#"{"scenario": {"a": {"nat_disabled": true, "nat_idle_timeout_s": 5}}}"#[..],
        &b"{\"scenario\": "[..],
        &b""[..],
        &b"\xff\xfe\x00garbage"[..],
    ] {
        let resp = client_request(
            &addr,
            "POST",
            "/sweep",
            Some("application/toml"),
            body,
        )
        .unwrap();
        assert_eq!(resp.status, 400, "body {body:?} must be rejected");
        let doc = json::parse(resp.body_str().trim()).unwrap();
        assert_eq!(
            doc.get("error").and_then(|e| e.as_str()),
            Some("bad_request"),
            "{}",
            resp.body_str()
        );
        assert!(
            doc.get("detail").and_then(|d| d.as_str()).is_some(),
            "{}",
            resp.body_str()
        );
    }
    // zero sweeps actually ran
    let metrics =
        client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    assert!(
        metrics
            .body_str()
            .contains("icecloud_sweep_computations_total 0"),
        "{}",
        metrics.body_str()
    );

    handle.shutdown();
}

#[test]
fn oversized_body_gets_413() {
    let (handle, addr) = start_server();
    let huge = vec![b'a'; 2 * 1024 * 1024];
    let resp = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        &huge,
    )
    .unwrap();
    assert_eq!(resp.status, 413);
    handle.shutdown();
}

#[test]
fn keep_alive_serves_multiple_requests_per_connection() {
    let (handle, addr) = start_server();
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n\
              GET /matrix HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
    let mut reader = BufReader::new(stream);
    let first = read_client_response(&mut reader).unwrap();
    assert_eq!(first.status, 200);
    assert_eq!(first.header("connection"), Some("keep-alive"));
    let second = read_client_response(&mut reader).unwrap();
    assert_eq!(second.status, 200);
    assert_eq!(second.header("connection"), Some("close"));
    assert!(second.body_str().contains("baseline"));
    handle.shutdown();
}

/// The acceptance criterion: 8 concurrent identical POSTs → exactly one
/// underlying replay, 8 byte-identical responses, 7 reported cache hits.
#[test]
fn concurrent_identical_posts_replay_once() {
    let (handle, addr) = start_server();
    let spec = b"[scenario.shared]\nbudget_usd = 30.0\n".to_vec();
    let barrier = Arc::new(Barrier::new(8));
    let mut clients = Vec::new();
    for _ in 0..8 {
        let addr = addr.clone();
        let spec = spec.clone();
        let barrier = Arc::clone(&barrier);
        clients.push(std::thread::spawn(move || {
            barrier.wait();
            client_request(
                &addr,
                "POST",
                "/sweep",
                Some("application/toml"),
                &spec,
            )
            .unwrap()
        }));
    }
    let responses: Vec<_> =
        clients.into_iter().map(|c| c.join().unwrap()).collect();

    for resp in &responses {
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        assert_eq!(
            resp.body, responses[0].body,
            "all concurrent responses must be byte-identical"
        );
    }
    let misses = responses
        .iter()
        .filter(|r| r.header("x-cache") == Some("miss"))
        .count();
    assert_eq!(misses, 1, "exactly one request owned the replay");

    // server-side accounting agrees
    assert_eq!(handle.state().metrics.sweep_computation_count(), 1);
    assert_eq!(handle.state().metrics.cache_hit_count(), 7);
    let metrics =
        client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    let text = metrics.body_str();
    assert!(
        text.contains("icecloud_sweep_computations_total 1"),
        "{text}"
    );
    assert!(text.contains("icecloud_sweep_cache_hits_total 7"), "{text}");
    assert!(
        text.contains("icecloud_sweep_cache_misses_total 1"),
        "{text}"
    );
    assert!(
        text.contains("icecloud_scenario_replays_total 1"),
        "{text}"
    );

    handle.shutdown();
}

/// Distinct scenario specs must get distinct content addresses and each
/// trigger their own replay.
#[test]
fn distinct_specs_do_not_alias() {
    let (handle, addr) = start_server();
    let a = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        b"[scenario.s]\nseed = 1\n",
    )
    .unwrap();
    let b = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        b"[scenario.s]\nseed = 2\n",
    )
    .unwrap();
    assert_eq!(a.status, 200);
    assert_eq!(b.status, 200);
    assert_ne!(a.body, b.body);
    assert_eq!(handle.state().metrics.sweep_computation_count(), 2);
    handle.shutdown();
}
