//! Property-style spec fuzzing: randomly assembled scenario tables
//! either parse to *exactly* the config the spec asked for, or error —
//! never an `Ok` whose applied config silently differs from the spec.
//!
//! This is the contract the PR-9 cast fixes restored: before them,
//! `duration_days = -1.0` saturated to a zero-length campaign and
//! `ramp_targets = [4294967297]` truncated to a 1-GPU ramp, both under
//! citable scenario names.  The generator mixes absent / valid /
//! invalid values per key (mistyped types, out-of-range magnitudes,
//! non-finite floats, conflicting key pairs, typo'd key names) and the
//! property cross-checks every accepted parse against an independently
//! built expected `ScenarioConfig`.

use icecloud::config::{
    CampaignConfig, CheckpointPolicy, NatOverride, OutageSpec,
    PolicyMode, ProviderWeights, RampStep, DEFAULT_RESUME_OVERHEAD_S,
};
use icecloud::coordinator::ScenarioConfig;
use icecloud::sweep::parse_spec_json;
use icecloud::util::json::Json;
use icecloud::util::proptest::{ensure, forall, no_shrink};
use icecloud::util::rng::Rng;
use std::collections::BTreeMap;

const DAY: f64 = 86_400.0;
const HOUR: f64 = 3_600.0;

#[derive(Debug, Clone)]
struct Case {
    body: BTreeMap<String, Json>,
    /// At least one slot drew an invalid value: the parse MUST error.
    invalid: bool,
    /// What a fully valid draw must parse to, field for field.
    expect: ScenarioConfig,
}

fn bad_u64(r: &mut Rng) -> Json {
    match r.below(4) {
        0 => Json::from("42"),
        1 => Json::Num(-3.0),
        2 => Json::Num(2.5),
        _ => Json::Bool(true),
    }
}

/// Invalid where a finite non-negative number is required.
fn bad_duration(r: &mut Rng) -> Json {
    match r.below(5) {
        0 => Json::from("1.0"),
        1 => Json::Num(-1.0),
        2 => Json::Num(f64::NAN),
        3 => Json::Num(f64::INFINITY),
        _ => Json::Num(3.0e18), // finite, but seconds overflow u64
    }
}

fn policy_expected(name: &str) -> PolicyMode {
    match name {
        "paper" => PolicyMode::Fixed(ProviderWeights {
            aws: 0.15,
            gcp: 0.15,
            azure: 0.70,
        }),
        "uniform" => PolicyMode::Fixed(ProviderWeights {
            aws: 1.0 / 3.0,
            gcp: 1.0 / 3.0,
            azure: 1.0 / 3.0,
        }),
        "adaptive" => PolicyMode::Adaptive,
        "risk-aware" => PolicyMode::RiskAware,
        _ => unreachable!(),
    }
}

fn gen_case(r: &mut Rng) -> Case {
    let mut body = BTreeMap::new();
    let mut expect = ScenarioConfig::named("a");
    let mut invalid = false;

    // seed: u64
    match r.below(4) {
        0 => {}
        3 => {
            body.insert("seed".into(), bad_u64(r));
            invalid = true;
        }
        _ => {
            let v = r.below(1_000_000);
            body.insert("seed".into(), Json::from(v));
            expect.seed = Some(v);
        }
    }

    // duration_days: finite non-negative f64
    match r.below(4) {
        0 => {}
        3 => {
            body.insert("duration_days".into(), bad_duration(r));
            invalid = true;
        }
        _ => {
            let v = (r.below(40) + 1) as f64 * 0.25;
            body.insert("duration_days".into(), Json::from(v));
            expect.duration_s = Some((v * DAY) as u64);
        }
    }

    // budget_usd / preempt_multiplier: plain numbers, only the type is
    // checked (no range semantics)
    match r.below(4) {
        0 => {}
        3 => {
            body.insert("budget_usd".into(), Json::from("29000"));
            invalid = true;
        }
        _ => {
            let v = r.below(100_000) as f64;
            body.insert("budget_usd".into(), Json::from(v));
            expect.budget_usd = Some(v);
        }
    }
    match r.below(4) {
        0 => {}
        3 => {
            body.insert("preempt_multiplier".into(), Json::Bool(true));
            invalid = true;
        }
        _ => {
            let v = (r.below(100) + 1) as f64 / 10.0;
            body.insert("preempt_multiplier".into(), Json::from(v));
            expect.preempt_multiplier = Some(v);
        }
    }

    // keepalive_s: u64
    match r.below(4) {
        0 => {}
        3 => {
            body.insert("keepalive_s".into(), bad_u64(r));
            invalid = true;
        }
        _ => {
            let v = r.below(10_000);
            body.insert("keepalive_s".into(), Json::from(v));
            expect.keepalive_s = Some(v);
        }
    }

    // NAT: disabled XOR idle timeout; both at once is a conflict
    match r.below(6) {
        0 | 1 => {}
        2 => {
            body.insert("nat_disabled".into(), Json::Bool(true));
            expect.nat_override = Some(NatOverride::Disabled);
        }
        3 => {
            // present-but-false is a valid no-op
            body.insert("nat_disabled".into(), Json::Bool(false));
        }
        4 => {
            let v = r.below(1_000) + 1;
            body.insert("nat_idle_timeout_s".into(), Json::from(v));
            expect.nat_override = Some(NatOverride::IdleTimeout(v));
        }
        _ => {
            invalid = true;
            if r.chance(0.5) {
                body.insert("nat_disabled".into(), Json::Bool(true));
                body.insert("nat_idle_timeout_s".into(), Json::from(60u64));
            } else {
                body.insert("nat_disabled".into(), Json::from("true"));
            }
        }
    }

    // outage: disabled | rescheduled (at + optional duration) | broken
    match r.below(6) {
        0 | 1 => {}
        2 => {
            body.insert("outage_disabled".into(), Json::Bool(true));
            expect.outage = Some(None);
        }
        3 | 4 => {
            let at = (r.below(20) + 1) as f64 * 0.5;
            body.insert("outage_at_days".into(), Json::from(at));
            let dur = if r.chance(0.5) {
                let d = (r.below(12) + 1) as f64 * 0.5;
                body.insert(
                    "outage_duration_hours".into(),
                    Json::from(d),
                );
                d
            } else {
                2.0
            };
            expect.outage = Some(Some(OutageSpec {
                at_s: (at * DAY) as u64,
                duration_s: (dur * HOUR) as u64,
            }));
        }
        _ => {
            invalid = true;
            match r.below(4) {
                0 => {
                    body.insert(
                        "outage_at_days".into(),
                        bad_duration(r),
                    );
                }
                1 => {
                    body.insert("outage_at_days".into(), Json::from(1.0));
                    body.insert(
                        "outage_duration_hours".into(),
                        Json::Num(-2.0),
                    );
                }
                2 => {
                    // dangling duration: would silently vanish pre-fix
                    body.insert(
                        "outage_duration_hours".into(),
                        Json::from(2.0),
                    );
                }
                _ => {
                    body.insert(
                        "outage_disabled".into(),
                        Json::from("true"),
                    );
                }
            }
        }
    }

    // ramp: targets (u32 range) + optional holds (<= targets, finite
    // non-negative days)
    match r.below(6) {
        0 | 1 | 2 => {}
        3 | 4 => {
            let n = (r.below(3) + 1) as usize;
            let targets: Vec<u64> =
                (0..n).map(|_| r.below(100_000) + 1).collect();
            body.insert(
                "ramp_targets".into(),
                Json::Arr(targets.iter().map(|&t| Json::from(t)).collect()),
            );
            let holds: Vec<f64> = if r.chance(0.5) {
                let k = (r.below(n as u64 + 1)) as usize;
                (0..k).map(|_| (r.below(16) + 1) as f64 * 0.25).collect()
            } else {
                Vec::new()
            };
            if !holds.is_empty() {
                body.insert(
                    "ramp_hold_days".into(),
                    Json::Arr(
                        holds.iter().map(|&h| Json::from(h)).collect(),
                    ),
                );
            }
            expect.ramp = Some(
                targets
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| RampStep {
                        target: t as u32,
                        hold_s: (holds.get(i).copied().unwrap_or(2.0)
                            * DAY) as u64,
                    })
                    .collect(),
            );
        }
        _ => {
            invalid = true;
            match r.below(5) {
                0 => {
                    body.insert("ramp_targets".into(), Json::Arr(vec![]));
                }
                1 => {
                    // u32 overflow: pre-fix this ramped to 1 GPU
                    body.insert(
                        "ramp_targets".into(),
                        Json::Arr(vec![Json::Num(4_294_967_297.0)]),
                    );
                }
                2 => {
                    body.insert(
                        "ramp_targets".into(),
                        Json::Arr(vec![Json::Num(100.5)]),
                    );
                }
                3 => {
                    body.insert(
                        "ramp_targets".into(),
                        Json::Arr(vec![Json::from(100u64)]),
                    );
                    body.insert(
                        "ramp_hold_days".into(),
                        Json::Arr(vec![Json::Num(-1.0)]),
                    );
                }
                _ => {
                    body.insert(
                        "ramp_targets".into(),
                        Json::Arr(vec![Json::from(100u64)]),
                    );
                    body.insert(
                        "ramp_hold_days".into(),
                        Json::Arr(vec![
                            Json::from(1.0),
                            Json::from(2.0),
                        ]),
                    );
                }
            }
        }
    }

    // onprem_slots: u32 range
    match r.below(4) {
        0 | 1 => {}
        2 => {
            let v = r.below(100_000);
            body.insert("onprem_slots".into(), Json::from(v));
            expect.onprem_slots = Some(v as u32);
        }
        _ => {
            invalid = true;
            if r.chance(0.5) {
                // pre-fix: truncated modulo 2^32 to one slot
                body.insert(
                    "onprem_slots".into(),
                    Json::Num(4_294_967_297.0),
                );
            } else {
                body.insert("onprem_slots".into(), bad_u64(r));
            }
        }
    }

    // policy: a known name
    match r.below(4) {
        0 | 1 => {}
        2 => {
            let names = ["paper", "uniform", "adaptive", "risk-aware"];
            let name = names[r.below(4) as usize];
            body.insert("policy".into(), Json::from(name));
            expect.policy = Some(policy_expected(name));
        }
        _ => {
            invalid = true;
            if r.chance(0.5) {
                body.insert("policy".into(), Json::from("bogus"));
            } else {
                body.insert("policy".into(), Json::from(7u64));
            }
        }
    }

    // checkpoint: disabled XOR interval (+ optional overhead)
    match r.below(6) {
        0 | 1 => {}
        2 => {
            body.insert("checkpoint_disabled".into(), Json::Bool(true));
            expect.checkpoint = Some(CheckpointPolicy::None);
        }
        3 | 4 => {
            let every = r.below(7_200) + 1;
            body.insert("checkpoint_every_s".into(), Json::from(every));
            let overhead = if r.chance(0.5) {
                let o = r.below(600);
                body.insert(
                    "checkpoint_resume_overhead_s".into(),
                    Json::from(o),
                );
                o
            } else {
                DEFAULT_RESUME_OVERHEAD_S
            };
            expect.checkpoint = Some(CheckpointPolicy::Interval {
                every_s: every,
                resume_overhead_s: overhead,
            });
        }
        _ => {
            invalid = true;
            match r.below(4) {
                0 => {
                    body.insert(
                        "checkpoint_every_s".into(),
                        Json::from(0u64),
                    );
                }
                1 => {
                    body.insert(
                        "checkpoint_resume_overhead_s".into(),
                        Json::from(30u64),
                    );
                }
                2 => {
                    body.insert(
                        "checkpoint_disabled".into(),
                        Json::Bool(true),
                    );
                    body.insert(
                        "checkpoint_every_s".into(),
                        Json::from(900u64),
                    );
                }
                _ => {
                    body.insert(
                        "checkpoint_disabled".into(),
                        Json::Num(1.0),
                    );
                }
            }
        }
    }

    // sometimes a typo'd key rides along: must always reject
    if r.chance(0.1) {
        body.insert("budgett_usd".into(), Json::from(1.0));
        invalid = true;
    }

    Case { body, invalid, expect }
}

#[test]
fn random_specs_parse_exactly_or_error() {
    forall(
        "spec-parses-exactly-or-errors",
        0xC0FFEE,
        400,
        gen_case,
        no_shrink,
        |case| {
            let mut scenario = Json::obj();
            scenario.set("a", Json::Obj(case.body.clone()));
            let mut doc = Json::obj();
            doc.set("scenario", scenario);
            let mut base = CampaignConfig::default();
            match parse_spec_json(&doc, &mut base) {
                Err(e) => ensure(
                    case.invalid,
                    format!("valid spec rejected: {e}"),
                ),
                Ok(got) => {
                    ensure(
                        !case.invalid,
                        format!(
                            "invalid spec accepted as {:?}",
                            got.first()
                        ),
                    )?;
                    ensure(
                        got.len() == 1 && got[0] == case.expect,
                        format!(
                            "accepted config differs from spec:\n  \
                             got:  {:?}\n  want: {:?}",
                            got.first(),
                            case.expect
                        ),
                    )
                }
            }
        },
    );
}

/// Direct (non-random) regressions for the three PR-9 cast bugs, kept
/// alongside the fuzz so a failure names the exact bug.
#[test]
fn cast_corruption_regressions() {
    let parse_one = |key: &str, v: Json| {
        let mut body = Json::obj();
        body.set(key, v);
        let mut scenario = Json::obj();
        scenario.set("a", body);
        let mut doc = Json::obj();
        doc.set("scenario", scenario);
        parse_spec_json(&doc, &mut CampaignConfig::default())
    };
    // bug 1: negative / non-finite durations saturated to 0
    assert!(parse_one("duration_days", Json::Num(-1.0)).is_err());
    assert!(parse_one("duration_days", Json::Num(f64::NAN)).is_err());
    assert!(parse_one("outage_at_days", Json::Num(-3.0)).is_err());
    // bug 2: u32 truncation modulo 2^32
    assert!(parse_one(
        "ramp_targets",
        Json::Arr(vec![Json::Num(4_294_967_297.0)])
    )
    .is_err());
    assert!(
        parse_one("onprem_slots", Json::Num(4_294_967_297.0)).is_err()
    );
    // the boundary values stay legal
    let ok = parse_one("onprem_slots", Json::Num(u32::MAX as f64))
        .unwrap();
    assert_eq!(ok[0].onprem_slots, Some(u32::MAX));
    assert_eq!(
        parse_one("duration_days", Json::Num(0.0)).unwrap()[0]
            .duration_s,
        Some(0)
    );
}
