//! Adversarial-input properties for `util::json`.
//!
//! `icecloud serve` feeds untrusted HTTP request bodies into this
//! parser, so beyond the round-trip happy path it must *fail closed* on
//! hostile input: deep nesting must error (not blow the stack), huge
//! numbers must error (not round-trip as null), truncation and invalid
//! escapes must error, and duplicate keys must resolve deterministically.
//! Randomized properties run on `util::proptest`; the named attacks are
//! pinned as fixed regression cases.

use icecloud::util::json::{self, Json};
use icecloud::util::proptest::{ensure, forall, no_shrink, shrink_vec};
use icecloud::util::rng::Rng;

// ---- generators ----------------------------------------------------------

/// A random JSON tree of bounded depth/width.
fn gen_value(rng: &mut Rng, depth: u64) -> Json {
    let choice = if depth == 0 { rng.below(5) } else { rng.below(7) };
    match choice {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            // mix integers, fractions, negatives
            let mag = rng.below(1_000_000) as f64;
            match rng.below(3) {
                0 => Json::Num(mag),
                1 => Json::Num(-mag),
                _ => Json::Num(mag / 128.0),
            }
        }
        3 => Json::Str(gen_string(rng)),
        4 => Json::Str(String::new()),
        5 => Json::Arr(
            (0..rng.below(4))
                .map(|_| gen_value(rng, depth - 1))
                .collect(),
        ),
        _ => {
            let mut o = Json::obj();
            for _ in 0..rng.below(4) {
                o.set(&gen_string(rng), gen_value(rng, depth - 1));
            }
            o
        }
    }
}

/// Strings that exercise escaping: quotes, backslashes, control chars,
/// multi-byte UTF-8.
fn gen_string(rng: &mut Rng) -> String {
    const ALPHABET: [&str; 12] = [
        "a", "Z", "0", "\"", "\\", "\n", "\t", "\u{0007}", "é", "☃",
        "𝄞", " ",
    ];
    (0..rng.below(8))
        .map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize])
        .collect()
}

/// Random bytes from a JSON-ish alphabet: mostly structural characters,
/// so a meaningful fraction of inputs are *almost* valid.
fn gen_garbage(rng: &mut Rng) -> String {
    const ALPHABET: &[u8] = br#"{}[]",:0123456789.eE+-truefalsn\ "#;
    let len = rng.below(40) as usize;
    (0..len)
        .map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize] as char)
        .collect()
}

// ---- randomized properties ----------------------------------------------

#[test]
fn prop_roundtrip_compact_and_pretty() {
    forall(
        "json-roundtrip",
        0x1CE0,
        300,
        |rng| gen_value(rng, 3),
        no_shrink,
        |v| {
            let compact = json::parse(&v.to_string_compact())
                .map_err(|e| format!("compact reparse failed: {e}"))?;
            ensure(compact == *v, "compact round-trip changed the value")?;
            let pretty = json::parse(&v.to_string_pretty())
                .map_err(|e| format!("pretty reparse failed: {e}"))?;
            ensure(pretty == *v, "pretty round-trip changed the value")
        },
    );
}

#[test]
fn prop_parser_never_panics_on_garbage() {
    // the property *is* "returns Ok or Err without panicking": a panic
    // fails the test through the harness
    forall(
        "json-no-panic",
        0xDEAD,
        2000,
        gen_garbage,
        no_shrink,
        |s| {
            let _ = json::parse(s);
            Ok(())
        },
    );
}

#[test]
fn prop_valid_parse_is_stable_under_reserialization() {
    forall(
        "json-fixpoint",
        0xBEEF,
        500,
        gen_garbage,
        no_shrink,
        |s| match json::parse(s) {
            Err(_) => Ok(()),
            Ok(v) => {
                let once = v.to_string_compact();
                let twice = json::parse(&once)
                    .map_err(|e| format!("reparse failed: {e}"))?
                    .to_string_compact();
                ensure(once == twice, "serialization is not a fixpoint")
            }
        },
    );
}

#[test]
fn prop_deep_nesting_always_errors_never_crashes() {
    forall(
        "json-depth",
        7,
        40,
        |rng| {
            let depth = json::MAX_DEPTH + 1 + rng.below(5000) as usize;
            let open = if rng.below(2) == 0 { "[" } else { "{\"k\":" };
            open.repeat(depth)
        },
        no_shrink,
        |s| ensure(json::parse(s).is_err(), "over-deep input must error"),
    );
}

#[test]
fn prop_truncations_of_valid_documents_error() {
    forall(
        "json-truncate",
        11,
        200,
        |rng| {
            let mut full = gen_value(rng, 2).to_string_compact();
            if full.len() < 2 {
                full = "[null]".to_string(); // too short to truncate
            }
            let chars: Vec<char> = full.chars().collect();
            let cut = 1 + rng.below(chars.len() as u64 - 1) as usize;
            chars[..cut].iter().collect::<String>()
        },
        shrink_vec_string(),
        |prefix| {
            // a strict prefix of a compact document is either invalid or
            // a complete smaller value; it must never panic, and when it
            // parses, reserialization must be stable
            match json::parse(prefix) {
                Err(_) => Ok(()),
                Ok(v) => {
                    let s = v.to_string_compact();
                    let v2 = json::parse(&s)
                        .map_err(|e| format!("reparse failed: {e}"))?;
                    ensure(v2 == v, "unstable truncated parse")
                }
            }
        },
    );
}

/// Adapter: shrink a String by dropping characters via `shrink_vec`.
fn shrink_vec_string() -> impl Fn(&String) -> Vec<String> {
    |s: &String| {
        let chars: Vec<char> = s.chars().collect();
        shrink_vec(&chars)
            .into_iter()
            .map(|c| c.into_iter().collect())
            .collect()
    }
}

// ---- fixed regression cases ----------------------------------------------

#[test]
fn deep_nesting_attack_errors() {
    for open in ["[", "{\"a\":"] {
        let attack = open.repeat(100_000);
        assert!(json::parse(&attack).is_err(), "attack '{open}...' passed");
    }
    // balanced-but-deep is equally an error past the bound
    let balanced =
        format!("{}1{}", "[".repeat(5000), "]".repeat(5000));
    assert!(json::parse(&balanced).is_err());
    // legal depth still parses
    let fine = format!("{}1{}", "[".repeat(100), "]".repeat(100));
    assert!(json::parse(&fine).is_ok());
}

#[test]
fn huge_numbers_rejected_reasonable_numbers_kept() {
    assert!(json::parse("1e999").is_err());
    assert!(json::parse("-1e999").is_err());
    assert!(json::parse("[1, 2, 1e99999999]").is_err());
    assert_eq!(json::parse("1e308").unwrap().as_f64(), Some(1e308));
    let big = json::parse("123456789012345678901234567890")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(
        big.is_finite() && big > 1.23e29 && big < 1.24e29,
        "over-precise integers lose precision but stay finite: {big}"
    );
    // denormal-small collapses to zero rather than erroring
    assert_eq!(json::parse("1e-999").unwrap().as_f64(), Some(0.0));
}

#[test]
fn truncated_documents_error() {
    for src in [
        "{",
        "{\"a\"",
        "{\"a\":",
        "{\"a\":1,",
        "[1, 2",
        "\"unterminated",
        "\"escape at end\\",
        "tru",
        "-",
        "1e",
        "1e+",
    ] {
        assert!(json::parse(src).is_err(), "'{src}' must error");
    }
}

#[test]
fn invalid_escapes_rejected() {
    assert!(json::parse(r#""\q""#).is_err(), "unknown escape letter");
    assert!(json::parse(r#""\u12""#).is_err(), "short \\u escape");
    assert!(json::parse(r#""\uZZZZ""#).is_err(), "non-hex \\u escape");
    assert!(json::parse(r#""\u+123""#).is_err(), "sign in \\u escape");
    // valid escapes still work
    assert_eq!(
        json::parse(r#""A\n\t\\""#).unwrap().as_str(),
        Some("A\n\t\\")
    );
}

#[test]
fn lone_surrogates_become_replacement_chars() {
    // BMP-only \u handling: a lone surrogate cannot be a char, so the
    // parser substitutes U+FFFD instead of crashing (documented policy)
    assert_eq!(
        json::parse(r#""\ud800""#).unwrap().as_str(),
        Some("\u{FFFD}")
    );
}

#[test]
fn duplicate_keys_resolve_last_wins_deterministically() {
    let v = json::parse(r#"{"a": 1, "b": 0, "a": 2}"#).unwrap();
    assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
    assert_eq!(v.as_obj().unwrap().len(), 2);
    // and the resolution is stable across parses
    let again = json::parse(r#"{"a": 1, "b": 0, "a": 2}"#).unwrap();
    assert_eq!(v, again);
}

#[test]
fn control_characters_in_strings_must_be_escaped() {
    // raw control bytes inside a string are not valid JSON; our writer
    // always escapes them, so reject-on-read keeps the formats closed
    let raw = "\"line1\nline2\"";
    // the hand-rolled parser tolerates raw newlines (documented
    // leniency); what matters is the writer never produces them
    let _ = json::parse(raw);
    let mut o = Json::obj();
    o.set("s", Json::from("line1\nline2\u{0007}"));
    let written = o.to_string_compact();
    assert!(!written.contains('\n'), "writer must escape newlines");
    assert!(written.contains("\\n"));
    assert!(written.contains("\\u0007"));
    assert_eq!(json::parse(&written).unwrap(), o);
}

#[test]
fn enormous_flat_documents_parse_within_bounds() {
    // breadth is fine (the server bounds total body size, not width)
    let wide = format!(
        "[{}]",
        (0..10_000).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    );
    let v = json::parse(&wide).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 10_000);
}
