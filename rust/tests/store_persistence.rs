//! Restart and fault-injection tests for the persistent result store.
//!
//! The tentpole property: a result computed before a restart is served
//! after it — byte-identical, without recomputation — because the disk
//! tier (`server::store::DiskStore`) survives the process.  The fault
//! half: corrupted entries (truncation, bit rot, renames) are
//! quarantined — never served, never a panic — and `.tmp.` debris from
//! a crashed writer is cleaned on startup.  Everything here runs over
//! real sockets against real directories; each test gets its own
//! scratch root under the system temp dir.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{DiskStore, ServeConfig, Server, ServerHandle};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// A unique scratch root per test (std-only; no tempfile crate).
fn scratch() -> PathBuf {
    std::env::temp_dir().join(format!(
        "icecloud-store-e2e-{}-{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 2 * HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

fn start_server(store_dir: &std::path::Path) -> (ServerHandle, String) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 4,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 8,
        job_runners: 1,
        store_dir: Some(store_dir.to_path_buf()),
        base: tiny_base(),
        ..ServeConfig::default()
    })
    .expect("bind ephemeral port");
    let handle = server.spawn().expect("spawn server");
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn post_sweep(addr: &str, spec: &[u8]) -> icecloud::server::http::ClientResponse {
    client_request(addr, "POST", "/sweep", Some("application/toml"), spec)
        .expect("sweep request")
}

fn response_key(body: &[u8]) -> String {
    json::parse(std::str::from_utf8(body).unwrap().trim())
        .unwrap()
        .get("key")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

/// The tentpole: results survive a full server restart, are served
/// from disk without recomputation, and stay byte-identical.
#[test]
fn results_survive_restart() {
    let root = scratch();
    let spec = b"[scenario.keep]\n\n[scenario.tweak]\nseed = 5\n";

    let (first_body, key) = {
        let (handle, addr) = start_server(&root);
        let resp = post_sweep(&addr, spec);
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        assert_eq!(resp.header("x-cache"), Some("miss"));
        assert_eq!(handle.state().metrics.sweep_computation_count(), 1);
        let key = response_key(&resp.body);
        handle.shutdown();
        (resp.body, key)
    };

    // a brand-new process would see exactly this: fresh memory, same
    // directory
    let (handle, addr) = start_server(&root);
    let by_key = client_request(
        &addr,
        "GET",
        &format!("/results/{key}"),
        None,
        b"",
    )
    .unwrap();
    assert_eq!(by_key.status, 200);
    assert_eq!(by_key.header("x-cache"), Some("disk"));
    assert_eq!(by_key.body, first_body, "restart must not change bytes");

    // POST of the same spec is a disk hit, not a replay
    let again = post_sweep(&addr, spec);
    assert_eq!(again.status, 200);
    assert_eq!(
        again.header("x-cache"),
        Some("hit"),
        "the /results fetch promoted the entry into memory"
    );
    assert_eq!(again.body, first_body);
    assert_eq!(
        handle.state().metrics.sweep_computation_count(),
        0,
        "nothing recomputes after a restart"
    );
    assert!(handle.state().metrics.disk_hit_count() >= 1);
    let metrics =
        client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    let text = metrics.body_str();
    assert!(text.contains("icecloud_store_hits_total"), "{text}");
    assert!(text.contains("icecloud_result_store_entries 1"), "{text}");

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// The disk probe also covers the compute path: a cold POST on a
/// restart-warmed server replays nothing even without a prior
/// /results fetch.
#[test]
fn post_after_restart_is_a_disk_hit() {
    let root = scratch();
    let spec = b"[scenario.warm]\nbudget_usd = 33.0\n";
    {
        let (handle, addr) = start_server(&root);
        assert_eq!(post_sweep(&addr, spec).status, 200);
        handle.shutdown();
    }
    let (handle, addr) = start_server(&root);
    let resp = post_sweep(&addr, spec);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-cache"), Some("disk"));
    assert_eq!(handle.state().metrics.sweep_computation_count(), 0);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// Fault injection: a truncated entry file is quarantined on the next
/// startup scan — never served, never a panic — and the request
/// recomputes to the exact same bytes.
#[test]
fn corrupted_entry_is_quarantined_and_recomputed() {
    let root = scratch();
    let spec = b"[scenario.rot]\nseed = 9\n";
    let (first_body, key) = {
        let (handle, addr) = start_server(&root);
        let resp = post_sweep(&addr, spec);
        assert_eq!(resp.status, 200);
        let key = response_key(&resp.body);
        handle.shutdown();
        (resp.body, key)
    };

    // truncate the entry on disk
    let entry = root.join("entries").join(&key);
    let raw = std::fs::read(&entry).expect("entry file exists");
    std::fs::write(&entry, &raw[..raw.len() / 2]).unwrap();

    let (handle, addr) = start_server(&root);
    // the corrupt entry is gone from the index: by-key fetch is a 404
    let by_key = client_request(
        &addr,
        "GET",
        &format!("/results/{key}"),
        None,
        b"",
    )
    .unwrap();
    assert_eq!(by_key.status, 404, "quarantined entries must not serve");
    // ...and it sits in quarantine for post-mortem
    assert!(root.join("quarantine").join(&key).exists());
    assert!(!entry.exists());

    // recomputation reproduces the identical bytes (determinism) and
    // re-persists them
    let resp = post_sweep(&addr, spec);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-cache"), Some("miss"));
    assert_eq!(resp.body, first_body);
    assert_eq!(handle.state().metrics.sweep_computation_count(), 1);
    assert!(entry.exists(), "the recomputed entry is persisted again");

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// Bit rot *after* startup (the scan passed, the file changed later)
/// is caught by the per-read verification in `DiskStore::get`.
#[test]
fn bitrot_after_open_never_serves() {
    let root = scratch();
    let key = {
        let (handle, addr) = start_server(&root);
        let resp = post_sweep(&addr, b"[scenario.late-rot]\n");
        assert_eq!(resp.status, 200);
        let key = response_key(&resp.body);
        handle.shutdown();
        key
    };
    let store = DiskStore::open(&root).unwrap();
    assert!(store.contains(&key));
    let entry = root.join("entries").join(&key);
    let mut raw = std::fs::read(&entry).unwrap();
    let last = raw.len() - 1;
    raw[last] ^= 0x01;
    std::fs::write(&entry, &raw).unwrap();
    assert!(store.get(&key).is_none(), "rotted entry must not serve");
    assert_eq!(store.quarantined(), 1);
    assert_eq!(store.stats().0, 0);
    let _ = std::fs::remove_dir_all(&root);
}

/// Crash simulation: `.tmp.` files left by a writer that died before
/// its atomic rename are deleted on startup, and foreign files are
/// quarantined rather than trusted.
#[test]
fn crash_debris_cleaned_on_startup() {
    let root = scratch();
    {
        let (handle, addr) = start_server(&root);
        assert_eq!(post_sweep(&addr, b"[scenario.real]\n").status, 200);
        handle.shutdown();
    }
    let entries = root.join("entries");
    std::fs::write(entries.join(".tmp.4242.0"), b"torn half-write")
        .unwrap();
    std::fs::write(entries.join(".tmp.4242.1"), b"").unwrap();
    std::fs::write(entries.join("not-a-key"), b"who put this here")
        .unwrap();

    let (handle, addr) = start_server(&root);
    assert!(!entries.join(".tmp.4242.0").exists());
    assert!(!entries.join(".tmp.4242.1").exists());
    assert!(!entries.join("not-a-key").exists());
    assert!(root.join("quarantine").join("not-a-key").exists());
    // the one real entry still serves
    let metrics =
        client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    assert!(
        metrics.body_str().contains("icecloud_result_store_entries 1"),
        "{}",
        metrics.body_str()
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// Async jobs ride the same store: a job finished before a restart is
/// instantly `done` on resubmission afterwards, served from disk.
#[test]
fn async_resubmit_after_restart_completes_instantly() {
    let root = scratch();
    let spec = b"[scenario.job]\nseed = 21\n";
    let (job_body, id) = {
        let (handle, addr) = start_server(&root);
        let resp = client_request(
            &addr,
            "POST",
            "/sweep?mode=async",
            Some("application/toml"),
            spec,
        )
        .unwrap();
        assert_eq!(resp.status, 202, "{}", resp.body_str());
        let id = json::parse(resp.body_str().trim())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        // poll to completion
        let mut body = None;
        for _ in 0..3000 {
            let poll = client_request(
                &addr,
                "GET",
                &format!("/jobs/{id}"),
                None,
                b"",
            )
            .unwrap();
            let doc = json::parse(poll.body_str().trim()).unwrap();
            match doc.get("status").unwrap().as_str().unwrap() {
                "done" => {
                    let fetched = client_request(
                        &addr,
                        "GET",
                        &format!("/results/{id}"),
                        None,
                        b"",
                    )
                    .unwrap();
                    assert_eq!(fetched.status, 200);
                    body = Some(fetched.body);
                    break;
                }
                "failed" => panic!("job failed"),
                _ => std::thread::sleep(
                    std::time::Duration::from_millis(10),
                ),
            }
        }
        handle.shutdown();
        (body.expect("job finished"), id)
    };

    let (handle, addr) = start_server(&root);
    let resub = client_request(
        &addr,
        "POST",
        "/sweep?mode=async",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(resub.status, 202);
    let doc = json::parse(resub.body_str().trim()).unwrap();
    assert_eq!(doc.get("job_id").unwrap().as_str(), Some(id.as_str()));
    assert_eq!(
        doc.get("status").unwrap().as_str(),
        Some("done"),
        "a disk-resident result completes the job instantly"
    );
    let fetched = client_request(
        &addr,
        "GET",
        &format!("/results/{id}"),
        None,
        b"",
    )
    .unwrap();
    assert_eq!(fetched.body, job_body);
    assert_eq!(handle.state().metrics.sweep_computation_count(), 0);

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}
