//! Bit-exactness of the batched SoA photon engine against the scalar
//! reference walk, across seeds, shapes, bunch sizes, thread counts and
//! segment-sweep implementations (scalar helper vs the explicit-width
//! lane sweep of `runtime/simd.rs`).
//!
//! This is the determinism contract of DESIGN.md §13/§18: a photon's
//! walk is a pure function of `(inputs, pid)` (stateless counter RNG,
//! shared per-step helpers), and the summary is a pid-ordered fold of
//! the per-photon outcomes — so *any* execution plan must reproduce the
//! scalar oracle to the bit.  The `SimdMode::Lanes` leg of these
//! properties is the evidence behind shipping the lane sweep default-on
//! (the "bit-identical, not tolerance-checked" decision recorded in
//! DESIGN.md §18).  `tools/parity_check.py` extends the same chain one
//! language further, to `python/compile/kernels/ref.py`.

use icecloud::runtime::{
    build_inputs, ExecPlan, PhotonExecutable, SimdMode, VariantMeta,
};
use icecloud::util::proptest::{ensure, forall, no_shrink};

fn meta(photons: u64, doms: u64, steps: u64) -> VariantMeta {
    VariantMeta {
        name: format!("parity-{photons}x{doms}x{steps}"),
        file: "synthetic".into(),
        num_photons: photons,
        block: 128,
        num_doms: doms,
        num_steps: steps,
        num_layers: 10,
        flops_estimate: 1.0,
    }
}

/// The (threads, bunch) plans every property is checked under:
/// degenerate bunches, bunches that straddle chunk boundaries, more
/// threads than photons.  Each is run under both sweep modes.
const PLANS: [(usize, usize); 7] = [
    (1, 0),
    (1, 1),
    (1, 37),
    (2, 64),
    (3, 19),
    (8, 5),
    (0, 0), // auto threads, default bunch
];

/// Both pass-B sweep implementations; every plan axis crosses this one.
const SWEEPS: [SimdMode; 2] = [SimdMode::Off, SimdMode::Lanes];

#[test]
fn batched_is_bit_identical_to_scalar_across_shapes() {
    forall(
        "batched==scalar",
        0xC0FFEE,
        25,
        |r| {
            (
                r.below(500) + 1, // photons
                r.below(24) + 1,  // doms
                r.below(40) + 1,  // steps
                r.below(1 << 20), // seed
            )
        },
        no_shrink,
        |&(photons, doms, steps, seed)| {
            let exe = PhotonExecutable::from_meta(meta(photons, doms, steps))
                .expect("non-degenerate shape");
            let inputs = build_inputs(&exe.meta, seed as u32, true);
            let scalar = exe.run_scalar(&inputs).expect("scalar reference runs");
            for (threads, bunch) in PLANS {
                for simd in SWEEPS {
                    let plan = ExecPlan { threads, bunch, simd };
                    let batched = exe
                        .run_with_plan(&inputs, plan)
                        .expect("batched engine runs");
                    ensure(
                        batched.hits == scalar.hits,
                        format!("hits diverge under {plan:?} (seed {seed})"),
                    )?;
                    ensure(
                        batched.summary == scalar.summary,
                        format!(
                            "summary diverges under {plan:?} (seed {seed}): \
                             {:?} != {:?}",
                            batched.summary, scalar.summary
                        ),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lane_sweep_matches_scalar_at_every_tail_width() {
    // bunch sizes straddling the LANES=8 boundary: full vectors only,
    // pure tails, and every mixed split; each must be bit-identical
    let exe = PhotonExecutable::from_meta(meta(211, 9, 21)).unwrap();
    for seed in [0u32, 7, 1234] {
        let inputs = build_inputs(&exe.meta, seed, true);
        let scalar = exe.run_scalar(&inputs).unwrap();
        for bunch in [1usize, 3, 5, 7, 8, 9, 37, 64] {
            for threads in [1usize, 3] {
                let plan = ExecPlan { threads, bunch, simd: SimdMode::Lanes };
                let lanes = exe.run_with_plan(&inputs, plan).unwrap();
                assert_eq!(
                    lanes.hits, scalar.hits,
                    "hits, seed={seed} bunch={bunch} threads={threads}"
                );
                assert_eq!(
                    lanes.summary, scalar.summary,
                    "summary, seed={seed} bunch={bunch} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn thread_count_is_unobservable() {
    // a scaled-down cousin of the artifact "default" shape (the full
    // 4096 x 64 x 60 walk is a bench, not a debug-profile unit test)
    let exe = PhotonExecutable::from_meta(meta(1024, 24, 32)).unwrap();
    for seed in [0u32, 7, 20210921] {
        let inputs = build_inputs(&exe.meta, seed, true);
        let one = exe
            .run_with_plan(
                &inputs,
                ExecPlan { threads: 1, bunch: 4096, ..ExecPlan::default() },
            )
            .unwrap();
        for threads in [2usize, 3, 8] {
            for bunch in [100usize, 4096] {
                for simd in SWEEPS {
                    let many = exe
                        .run_with_plan(&inputs, ExecPlan { threads, bunch, simd })
                        .unwrap();
                    assert_eq!(
                        one.hits, many.hits,
                        "threads={threads} bunch={bunch} simd={simd:?}"
                    );
                    assert_eq!(
                        one.summary, many.summary,
                        "threads={threads} bunch={bunch} simd={simd:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_conserves_photons_under_every_plan() {
    let exe = PhotonExecutable::from_meta(meta(777, 12, 33)).unwrap();
    let inputs = build_inputs(&exe.meta, 99, true);
    for (threads, bunch) in PLANS {
        for simd in SWEEPS {
            let r = exe
                .run_with_plan(&inputs, ExecPlan { threads, bunch, simd })
                .unwrap();
            let total = r.summary[0] + r.summary[1] + r.summary[2];
            assert_eq!(total as u64, exe.meta.num_photons);
            assert_eq!(r.total_hits(), r.detected());
        }
    }
}

#[test]
fn default_plan_is_single_threaded_batched() {
    let exe = PhotonExecutable::from_meta(meta(64, 4, 8)).unwrap();
    assert_eq!(exe.plan(), ExecPlan::default());
    assert_eq!(ExecPlan::default().threads, 1);
    assert_eq!(ExecPlan::default().simd, SimdMode::Lanes);
    let inputs = build_inputs(&exe.meta, 5, true);
    assert_eq!(
        exe.run(&inputs).unwrap().summary,
        exe.run_with_plan(&inputs, ExecPlan::default()).unwrap().summary
    );
}

#[test]
fn with_plan_changes_wall_clock_only() {
    let exe = PhotonExecutable::from_meta(meta(2048, 30, 48))
        .unwrap()
        .with_plan(ExecPlan { threads: 4, bunch: 100, simd: SimdMode::Lanes });
    assert_eq!(
        exe.plan(),
        ExecPlan { threads: 4, bunch: 100, simd: SimdMode::Lanes }
    );
    let a = exe.run_seeded(3).unwrap();
    let b = exe
        .with_plan(ExecPlan { threads: 1, bunch: 0, simd: SimdMode::Off })
        .run_seeded(3)
        .unwrap();
    assert_eq!(a.hits, b.hits);
    assert_eq!(a.summary, b.summary);
}

#[test]
fn single_photon_bunch_works_under_threads() {
    // thread chunking must clamp to the photon count; a single photon
    // is also the smallest possible lane tail
    let exe = PhotonExecutable::from_meta(meta(1, 3, 5)).unwrap();
    let inputs = build_inputs(&exe.meta, 1, true);
    let scalar = exe.run_scalar(&inputs).unwrap();
    for simd in SWEEPS {
        let batched = exe
            .run_with_plan(&inputs, ExecPlan { threads: 32, bunch: 4096, simd })
            .unwrap();
        assert_eq!(scalar.summary, batched.summary, "simd={simd:?}");
    }
}
