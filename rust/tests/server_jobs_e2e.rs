//! End-to-end tests for the async job API over real sockets.
//!
//! Each test binds its own server on an ephemeral 127.0.0.1 port and
//! talks to it with the in-tree HTTP client, so submission, polling,
//! admission control and result fetching are exercised exactly as a
//! curl user would hit them.  The headline properties pinned here are
//! the PR's acceptance criteria: submit → poll → fetch returns bytes
//! identical to the blocking sync path, N duplicate async submissions
//! produce exactly one job, and a flooded admission queue sheds with
//! `429 + Retry-After` while `/healthz` keeps answering.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::server::http::client_request;
use icecloud::server::{ServeConfig, Server, ServerHandle};
use icecloud::sim::{DAY, HOUR};
use icecloud::util::json::{self, Json};

/// A campaign small enough that a replay takes milliseconds.
fn tiny_base() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 2 * HOUR;
    c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
    c.outage = None;
    c.onprem.slots = 8;
    c.generator.min_backlog = 30;
    c
}

fn start_server(cfg: ServeConfig) -> (ServerHandle, String) {
    let server = Server::bind(cfg).expect("bind ephemeral port");
    let handle = server.spawn().expect("spawn server");
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn default_server() -> (ServerHandle, String) {
    start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 2,
        cache_bytes: 1 << 20,
        queue_max: 16,
        job_runners: 2,
        store_dir: None,
        base: tiny_base(),
        ..ServeConfig::default()
    })
}

fn post_async(addr: &str, spec: &[u8]) -> icecloud::server::http::ClientResponse {
    client_request(
        addr,
        "POST",
        "/sweep?mode=async",
        Some("application/toml"),
        spec,
    )
    .expect("async submit")
}

fn parse_body(body: &[u8]) -> Json {
    json::parse(std::str::from_utf8(body).expect("utf-8 body").trim())
        .expect("json body")
}

/// Poll `/jobs/<id>` until the job reaches `done` (panics on `failed`
/// or timeout) and return the final job document.
fn wait_done(addr: &str, id: &str) -> Json {
    for _ in 0..3000 {
        let resp =
            client_request(addr, "GET", &format!("/jobs/{id}"), None, b"")
                .expect("poll");
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let doc = parse_body(&resp.body);
        let status = doc.get("status").unwrap().as_str().unwrap();
        match status {
            "done" => return doc,
            "failed" => panic!(
                "job failed: {:?}",
                doc.get("error").and_then(|e| e.as_str())
            ),
            _ => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    panic!("job {id} did not finish within the polling budget");
}

/// The acceptance criterion: submit → poll → fetch returns exactly the
/// bytes the blocking sync path returns for the same spec — both on
/// the same server (cache-mediated) and against a fresh server that
/// has to compute from scratch.
#[test]
fn async_lifecycle_matches_sync_bytes() {
    let (handle, addr) = default_server();
    let spec = b"[scenario.a]\n\n[scenario.b]\nseed = 11\n";

    let resp = post_async(&addr, spec);
    assert_eq!(resp.status, 202, "{}", resp.body_str());
    let doc = parse_body(&resp.body);
    let id = doc.get("job_id").unwrap().as_str().unwrap().to_string();
    assert_eq!(id.len(), 64, "job ids are sweep content addresses");
    assert_eq!(
        resp.header("location"),
        Some(format!("/jobs/{id}").as_str())
    );
    assert_eq!(
        doc.get("poll").unwrap().as_str(),
        Some(format!("/jobs/{id}").as_str())
    );

    let job = wait_done(&addr, &id);
    assert_eq!(
        job.get("result").unwrap().as_str(),
        Some(format!("/results/{id}").as_str())
    );
    assert!(job.get("run_s").unwrap().as_f64().unwrap() >= 0.0);

    let fetched = client_request(
        &addr,
        "GET",
        &format!("/results/{id}"),
        None,
        b"",
    )
    .unwrap();
    assert_eq!(fetched.status, 200);
    // the fetched body names its own content address
    assert_eq!(
        parse_body(&fetched.body).get("key").unwrap().as_str(),
        Some(id.as_str())
    );

    // same server, sync path: a cache hit with identical bytes
    let sync = client_request(
        &addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(sync.status, 200);
    assert_eq!(sync.header("x-cache"), Some("hit"));
    assert_eq!(sync.body, fetched.body);

    // fresh server, sync path: an actual replay, still identical bytes
    let (fresh_handle, fresh_addr) = default_server();
    let fresh = client_request(
        &fresh_addr,
        "POST",
        "/sweep",
        Some("application/toml"),
        spec,
    )
    .unwrap();
    assert_eq!(fresh.status, 200);
    assert_eq!(fresh.header("x-cache"), Some("miss"));
    assert_eq!(
        fresh.body, fetched.body,
        "async and sync computations must be byte-identical"
    );

    // exactly one replay happened on the original server
    assert_eq!(handle.state().metrics.sweep_computation_count(), 1);

    fresh_handle.shutdown();
    handle.shutdown();
}

/// N duplicate async submissions single-flight into exactly one job.
#[test]
fn duplicate_async_submits_produce_one_job() {
    let (handle, addr) = default_server();
    let spec = b"[scenario.dup]\nbudget_usd = 25.0\n".to_vec();

    let mut clients = Vec::new();
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(8));
    for _ in 0..8 {
        let addr = addr.clone();
        let spec = spec.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        clients.push(std::thread::spawn(move || {
            barrier.wait();
            post_async(&addr, &spec)
        }));
    }
    let responses: Vec<_> =
        clients.into_iter().map(|c| c.join().unwrap()).collect();

    let mut ids = Vec::new();
    for resp in &responses {
        assert_eq!(resp.status, 202, "{}", resp.body_str());
        let doc = parse_body(&resp.body);
        ids.push(doc.get("job_id").unwrap().as_str().unwrap().to_string());
    }
    for id in &ids {
        assert_eq!(id, &ids[0], "every duplicate names the same job");
    }
    wait_done(&addr, &ids[0]);

    // one tracked job, one underlying replay
    let listing =
        client_request(&addr, "GET", "/jobs", None, b"").unwrap();
    let doc = parse_body(&listing.body);
    assert_eq!(doc.get("count").unwrap().as_u64(), Some(1));
    assert_eq!(handle.state().metrics.sweep_computation_count(), 1);

    handle.shutdown();
}

/// Saturation: with one runner wedged on a long replay and a 2-slot
/// queue, a burst of distinct submissions must shed with 429 +
/// Retry-After — and `/healthz` must keep answering throughout.
#[test]
fn flooded_queue_sheds_with_429_and_healthz_stays_up() {
    let (handle, addr) = start_server(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 8,
        replay_threads: 1,
        cache_bytes: 1 << 20,
        queue_max: 2,
        job_runners: 1,
        store_dir: None,
        base: tiny_base(),
        ..ServeConfig::default()
    });

    // wedge the single runner on a genuinely slow replay (days of sim
    // time at a bigger fleet than the tiny base)
    let slow = post_async(
        &addr,
        b"[scenario.slow]\nduration_days = 6.0\nramp_targets = [200]\n",
    );
    assert_eq!(slow.status, 202, "{}", slow.body_str());

    // burst of distinct cheap jobs: 2 fit the queue, the rest shed
    let mut accepted = 0u32;
    let mut shed = 0u32;
    let mut saw_retry_after = false;
    for i in 0..24u32 {
        let spec = format!("[scenario.flood]\nseed = {i}\n");
        let resp = post_async(&addr, spec.as_bytes());
        match resp.status {
            202 => accepted += 1,
            429 => {
                shed += 1;
                let retry: u64 = resp
                    .header("retry-after")
                    .expect("429 carries Retry-After")
                    .parse()
                    .expect("Retry-After is integral seconds");
                assert!(retry >= 1);
                saw_retry_after = true;
            }
            other => panic!("unexpected status {other}: {}", resp.body_str()),
        }
        // the server must stay responsive mid-flood
        if i == 12 {
            let health = client_request(
                &addr, "GET", "/healthz", None, b"",
            )
            .unwrap();
            assert_eq!(health.status, 200);
        }
    }
    assert!(accepted >= 1, "some submissions fit the queue");
    assert!(shed >= 1, "a 24-burst into a 2-slot queue must shed");
    assert!(saw_retry_after);

    // liveness after the flood, and accounting agrees
    let health =
        client_request(&addr, "GET", "/healthz", None, b"").unwrap();
    assert_eq!(health.status, 200);
    assert_eq!(handle.state().metrics.jobs_shed_count(), shed as u64);
    let metrics =
        client_request(&addr, "GET", "/metrics", None, b"").unwrap();
    let text = metrics.body_str();
    assert!(
        text.contains(&format!("icecloud_jobs_shed_total {shed}")),
        "{text}"
    );

    handle.shutdown();
}

/// The status endpoints: field shape on a finished job, 404/405 on
/// unknown ids and wrong methods, and strict query validation.
#[test]
fn job_status_endpoints_report_fields_and_reject_garbage() {
    let (handle, addr) = default_server();

    let resp = post_async(&addr, b"[scenario.q]\nseed = 77\n");
    assert_eq!(resp.status, 202);
    let id = parse_body(&resp.body)
        .get("job_id")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    let job = wait_done(&addr, &id);
    assert!(job.get("age_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(job.get("wait_s").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(job.get("scenarios").unwrap().as_u64(), Some(1));
    assert!(job.get("queue_position").is_none());

    // unknown ids and wrong methods
    let missing = client_request(
        &addr,
        "GET",
        &format!("/jobs/{}", "0".repeat(64)),
        None,
        b"",
    )
    .unwrap();
    assert_eq!(missing.status, 404);
    let bad_method =
        client_request(&addr, "POST", "/jobs", None, b"").unwrap();
    assert_eq!(bad_method.status, 405);
    assert_eq!(bad_method.header("allow"), Some("GET"));

    // bad query strings are rejected up front, not queued
    let bad_query = client_request(
        &addr,
        "POST",
        "/sweep?mode=nope",
        Some("application/toml"),
        b"[scenario.x]\n",
    )
    .unwrap();
    assert_eq!(bad_query.status, 400);

    handle.shutdown();
}
