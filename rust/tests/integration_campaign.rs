//! Cross-module integration tests: the whole stack composed through the
//! public API, at reduced scale.

use icecloud::config::{CampaignConfig, OutageSpec, PolicyMode, ProviderWeights, RampStep};
use icecloud::coordinator::Campaign;
use icecloud::experiments::{fig1, fig2, headline};
use icecloud::sim::{DAY, HOUR, MINUTE};

fn base_config() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 3 * DAY;
    c.ramp = vec![
        RampStep { target: 30, hold_s: 6 * HOUR },
        RampStep { target: 100, hold_s: 60 * DAY },
    ];
    c.outage = Some(OutageSpec { at_s: 2 * DAY, duration_s: 2 * HOUR });
    c.post_outage_target = 50;
    c.low_budget_resume_fraction = 1.1;
    c.onprem.slots = 80;
    c.generator.min_backlog = 300;
    c
}

#[test]
fn full_stack_reproduces_fig1_shape() {
    let result = Campaign::new(base_config()).run();
    let fig = fig1::extract(&result);
    let checks = fig.checks();
    assert!(checks.peak >= 85.0, "peak={}", checks.peak);
    assert!(checks.collapse_min <= 5.0, "collapse={}", checks.collapse_min);
    assert!(checks.resume_level >= 35.0 && checks.resume_level <= 65.0,
            "resume={}", checks.resume_level);
    assert!(checks.ramp_monotonic_until_peak);
}

#[test]
fn full_stack_reproduces_fig2_doubling() {
    let mut c = base_config();
    // match cloud scale to on-prem scale so the factor is ~2x
    c.ramp = vec![RampStep { target: 85, hold_s: 60 * DAY }];
    c.outage = None;
    let result = Campaign::new(c).run();
    let fig = fig2::extract(&result);
    assert!(
        fig.expansion_factor > 1.6 && fig.expansion_factor < 2.4,
        "factor={}",
        fig.expansion_factor
    );
}

#[test]
fn headline_shape_holds_end_to_end() {
    let result = Campaign::new(base_config()).run();
    let h = headline::extract(&result);
    h.check_shape().unwrap();
    assert!(h.total_cost_usd > 0.0);
    assert!(h.goodput_fraction > 0.8, "goodput={}", h.goodput_fraction);
    // cost consistency: ledger total == sum of provider meters (+overhead)
    let meter_total = result.meter.total_spend();
    assert!((h.total_cost_usd - meter_total).abs() < 1e-6);
}

#[test]
fn cost_scales_with_fleet_size() {
    let run = |gpus: u32| {
        let mut c = base_config();
        c.outage = None;
        c.duration_s = DAY;
        c.ramp = vec![RampStep { target: gpus, hold_s: 60 * DAY }];
        Campaign::new(c).run().ledger.total_spent()
    };
    let small = run(50);
    let large = run(200);
    assert!(large > small * 3.0, "small={small} large={large}");
}

#[test]
fn onprem_only_baseline_has_no_cloud_spend() {
    let mut c = base_config();
    c.ramp = vec![RampStep { target: 0, hold_s: 60 * DAY }];
    c.outage = None;
    let result = Campaign::new(c).run();
    assert_eq!(result.ledger.total_spent(), 0.0);
    assert_eq!(result.usage.total_cloud_gpu_hours(), 0.0);
    assert!(result.usage.total_onprem_gpu_hours() > 0.0);
    assert!(result.schedd_stats.completed > 0);
}

#[test]
fn adaptive_policy_runs_and_favors_azure() {
    let mut c = base_config();
    c.policy = PolicyMode::Adaptive;
    c.outage = None;
    let result = Campaign::new(c).run();
    let azure_hours = result.provider_ops[2].2;
    let aws_hours = result.provider_ops[0].2;
    assert!(
        azure_hours > aws_hours,
        "adaptive must favor cheap+stable azure ({azure_hours} vs {aws_hours})"
    );
}

#[test]
fn uniform_policy_spreads_load() {
    let mut c = base_config();
    c.policy = PolicyMode::Fixed(ProviderWeights {
        aws: 1.0 / 3.0,
        gcp: 1.0 / 3.0,
        azure: 1.0 / 3.0,
    });
    c.outage = None;
    let result = Campaign::new(c).run();
    let (aws, gcp, azure) = (
        result.provider_ops[0].2,
        result.provider_ops[1].2,
        result.provider_ops[2].2,
    );
    let max = aws.max(gcp).max(azure);
    let min = aws.min(gcp).min(azure);
    assert!(min > 0.6 * max, "uniform spread: {aws:.0}/{gcp:.0}/{azure:.0}");
}

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir().join("icecloud-it-config");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("campaign.toml");
    std::fs::write(
        &path,
        r#"
seed = 99
duration_days = 1.0
keepalive_s = 120

[budget]
total_usd = 500.0

[ramp]
targets = [25]
hold_days = [10.0]

[outage]
disabled = true
"#,
    )
    .unwrap();
    let cfg = CampaignConfig::from_toml_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.seed, 99);
    assert_eq!(cfg.keepalive_s, 120);
    assert!(cfg.outage.is_none());
    let result = Campaign::new(cfg).run();
    assert!(result.schedd_stats.completed > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn monitoring_csv_has_aligned_series() {
    let result = Campaign::new(base_config()).run();
    let csv = result
        .monitor
        .to_csv(&["gpus.total", "gpus.azure", "jobs.running"]);
    let lines: Vec<&str> = csv.lines().collect();
    assert!(lines.len() > 50);
    assert_eq!(lines[0], "t_s,gpus.total,gpus.azure,jobs.running");
    // every row has 4 fields
    for line in &lines[1..] {
        assert_eq!(line.split(',').count(), 4, "bad row: {line}");
    }
}

#[test]
fn tick_cadence_change_preserves_shape() {
    // coarser control cadence must not change the macro outcome much
    let mut fine = base_config();
    fine.outage = None;
    fine.duration_s = DAY;
    let mut coarse = fine.clone();
    coarse.control_period_s = 15 * MINUTE;
    let a = Campaign::new(fine).run();
    let b = Campaign::new(coarse).run();
    let ga = a.monitor.get("gpus.total").unwrap().mean();
    let gb = b.monitor.get("gpus.total").unwrap().mean();
    assert!((ga - gb).abs() / ga < 0.15, "fine={ga} coarse={gb}");
}

#[test]
fn badput_stays_bounded_with_tuned_keepalive() {
    let mut c = base_config();
    c.outage = None;
    let result = Campaign::new(c).run();
    let good = result.schedd_stats.goodput_s as f64;
    let bad = result.schedd_stats.badput_s as f64;
    // spot churn exists, but badput must stay a small fraction
    assert!(bad / (good + bad) < 0.1, "badput fraction {}", bad / (good + bad));
}
