//! Cross-module integration tests: the whole stack composed through the
//! public API, at reduced scale.

use icecloud::cloud::Provider;
use icecloud::config::{
    CampaignConfig, CheckpointPolicy, OutageSpec, PolicyMode,
    ProviderWeights, RampStep,
};
use icecloud::coordinator::{Campaign, ScenarioConfig};
use icecloud::experiments::{fig1, fig2, headline};
use icecloud::sim::{DAY, HOUR, MINUTE};

fn base_config() -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.duration_s = 3 * DAY;
    c.ramp = vec![
        RampStep { target: 30, hold_s: 6 * HOUR },
        RampStep { target: 100, hold_s: 60 * DAY },
    ];
    c.outage = Some(OutageSpec { at_s: 2 * DAY, duration_s: 2 * HOUR });
    c.post_outage_target = 50;
    c.low_budget_resume_fraction = 1.1;
    c.onprem.slots = 80;
    c.generator.min_backlog = 300;
    c
}

#[test]
fn full_stack_reproduces_fig1_shape() {
    let result = Campaign::new(base_config()).run();
    let fig = fig1::extract(&result);
    let checks = fig.checks();
    assert!(checks.peak >= 85.0, "peak={}", checks.peak);
    assert!(checks.collapse_min <= 5.0, "collapse={}", checks.collapse_min);
    assert!(checks.resume_level >= 35.0 && checks.resume_level <= 65.0,
            "resume={}", checks.resume_level);
    assert!(checks.ramp_monotonic_until_peak);
}

#[test]
fn full_stack_reproduces_fig2_doubling() {
    let mut c = base_config();
    // match cloud scale to on-prem scale so the factor is ~2x
    c.ramp = vec![RampStep { target: 85, hold_s: 60 * DAY }];
    c.outage = None;
    let result = Campaign::new(c).run();
    let fig = fig2::extract(&result);
    assert!(
        fig.expansion_factor > 1.6 && fig.expansion_factor < 2.4,
        "factor={}",
        fig.expansion_factor
    );
}

#[test]
fn headline_shape_holds_end_to_end() {
    let result = Campaign::new(base_config()).run();
    let h = headline::extract(&result);
    h.check_shape().unwrap();
    assert!(h.total_cost_usd > 0.0);
    assert!(h.goodput_fraction > 0.8, "goodput={}", h.goodput_fraction);
    // cost consistency: ledger total == sum of provider meters (+overhead)
    let meter_total = result.meter.total_spend();
    assert!((h.total_cost_usd - meter_total).abs() < 1e-6);
}

#[test]
fn cost_scales_with_fleet_size() {
    let run = |gpus: u32| {
        let mut c = base_config();
        c.outage = None;
        c.duration_s = DAY;
        c.ramp = vec![RampStep { target: gpus, hold_s: 60 * DAY }];
        Campaign::new(c).run().ledger.total_spent()
    };
    let small = run(50);
    let large = run(200);
    assert!(large > small * 3.0, "small={small} large={large}");
}

#[test]
fn onprem_only_baseline_has_no_cloud_spend() {
    let mut c = base_config();
    c.ramp = vec![RampStep { target: 0, hold_s: 60 * DAY }];
    c.outage = None;
    let result = Campaign::new(c).run();
    assert_eq!(result.ledger.total_spent(), 0.0);
    assert_eq!(result.usage.total_cloud_gpu_hours(), 0.0);
    assert!(result.usage.total_onprem_gpu_hours() > 0.0);
    assert!(result.schedd_stats.completed > 0);
}

#[test]
fn adaptive_policy_runs_and_favors_azure() {
    let mut c = base_config();
    c.policy = PolicyMode::Adaptive;
    c.outage = None;
    let result = Campaign::new(c).run();
    let azure_hours = result.provider_ops[2].2;
    let aws_hours = result.provider_ops[0].2;
    assert!(
        azure_hours > aws_hours,
        "adaptive must favor cheap+stable azure ({azure_hours} vs {aws_hours})"
    );
}

#[test]
fn uniform_policy_spreads_load() {
    let mut c = base_config();
    c.policy = PolicyMode::Fixed(ProviderWeights {
        aws: 1.0 / 3.0,
        gcp: 1.0 / 3.0,
        azure: 1.0 / 3.0,
    });
    c.outage = None;
    let result = Campaign::new(c).run();
    let (aws, gcp, azure) = (
        result.provider_ops[0].2,
        result.provider_ops[1].2,
        result.provider_ops[2].2,
    );
    let max = aws.max(gcp).max(azure);
    let min = aws.min(gcp).min(azure);
    assert!(min > 0.6 * max, "uniform spread: {aws:.0}/{gcp:.0}/{azure:.0}");
}

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir().join("icecloud-it-config");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("campaign.toml");
    std::fs::write(
        &path,
        r#"
seed = 99
duration_days = 1.0
keepalive_s = 120

[budget]
total_usd = 500.0

[ramp]
targets = [25]
hold_days = [10.0]

[outage]
disabled = true
"#,
    )
    .unwrap();
    let cfg = CampaignConfig::from_toml_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.seed, 99);
    assert_eq!(cfg.keepalive_s, 120);
    assert!(cfg.outage.is_none());
    let result = Campaign::new(cfg).run();
    assert!(result.schedd_stats.completed > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn monitoring_csv_has_aligned_series() {
    let result = Campaign::new(base_config()).run();
    let csv = result
        .monitor
        .to_csv(&["gpus.total", "gpus.azure", "jobs.running"]);
    let lines: Vec<&str> = csv.lines().collect();
    assert!(lines.len() > 50);
    assert_eq!(lines[0], "t_s,gpus.total,gpus.azure,jobs.running");
    // every row has 4 fields
    for line in &lines[1..] {
        assert_eq!(line.split(',').count(), 4, "bad row: {line}");
    }
}

#[test]
fn tick_cadence_change_preserves_shape() {
    // coarser control cadence must not change the macro outcome much
    let mut fine = base_config();
    fine.outage = None;
    fine.duration_s = DAY;
    let mut coarse = fine.clone();
    coarse.control_period_s = 15 * MINUTE;
    let a = Campaign::new(fine).run();
    let b = Campaign::new(coarse).run();
    let ga = a.monitor.get("gpus.total").unwrap().mean().unwrap();
    let gb = b.monitor.get("gpus.total").unwrap().mean().unwrap();
    assert!((ga - gb).abs() / ga < 0.15, "fine={ga} coarse={gb}");
}

/// The goodput conservation identity of DESIGN.md §15: for every
/// provider, the billed busy (job-executing) wall seconds decompose
/// exactly into settled goodput + settled badput + attempts still in
/// flight at campaign end — under both checkpoint policies and both
/// spot-market weathers.  Nothing is double-counted, nothing leaks.
#[test]
fn goodput_plus_badput_plus_inflight_is_busy_time_per_provider() {
    let policies = [
        CheckpointPolicy::None,
        CheckpointPolicy::Interval { every_s: 1800, resume_overhead_s: 120 },
    ];
    for mult in [1.0, 4.0] {
        for ckpt in policies {
            let mut c = base_config();
            c.duration_s = DAY;
            c.outage = Some(OutageSpec { at_s: 12 * HOUR, duration_s: HOUR });
            c.preempt_multiplier = mult;
            c.checkpoint = ckpt;
            let result = Campaign::new(c).run();
            let mut settled_good = 0u64;
            let mut settled_bad = 0u64;
            for (i, p) in Provider::ALL.into_iter().enumerate() {
                let w = result.provider_work[i];
                let busy_s = result.meter.provider(p).busy_hours * 3600.0;
                let split = (w.goodput_s + w.badput_s + w.inflight_s) as f64;
                assert!(
                    (busy_s - split).abs() < 1.0,
                    "{:?} mult={mult} ckpt={ckpt:?}: busy {busy_s} != \
                     goodput+badput+inflight {split}",
                    p,
                );
                settled_good += w.goodput_s;
                settled_bad += w.badput_s;
            }
            // cloud-settled work never exceeds what the schedd settled
            // (the schedd totals additionally cover on-prem slots)
            assert!(settled_good <= result.schedd_stats.goodput_s);
            assert!(settled_bad <= result.schedd_stats.badput_s);
            if mult > 1.0 {
                assert!(
                    result.schedd_stats.interrupted > 0,
                    "x4 churn must interrupt something"
                );
            }
        }
    }
}

/// The PR 5 acceptance sweep: over checkpoint={none,interval} ×
/// preempt_multiplier={1,4}, checkpointing strictly reduces wasted
/// instance-hours, cost stays within budget, and the whole table is
/// deterministic (same seed → byte-identical JSON rendering).
#[test]
fn checkpointing_strictly_reduces_wasted_hours_across_churn() {
    let mut base = base_config();
    base.duration_s = DAY;
    base.budget_usd = 5_000.0;
    // the outage guarantees interrupted attempts even in calm markets
    base.outage = Some(OutageSpec { at_s: 12 * HOUR, duration_s: HOUR });

    let ckpt = CheckpointPolicy::Interval {
        every_s: 1800,
        resume_overhead_s: 120,
    };
    let mut scenarios = Vec::new();
    for (mult, policy, name) in [
        (1.0, None, "m1-none"),
        (1.0, Some(ckpt), "m1-ckpt"),
        (4.0, None, "m4-none"),
        (4.0, Some(ckpt), "m4-ckpt"),
    ] {
        let mut s = ScenarioConfig::named(name);
        s.preempt_multiplier = Some(mult);
        s.checkpoint = policy;
        scenarios.push(s);
    }
    let rows = icecloud::sweep::run_matrix(&base, &scenarios, 2);
    let by_name = |n: &str| {
        rows.iter().find(|r| r.name == n).expect("scenario row present")
    };
    for (none, with) in [("m1-none", "m1-ckpt"), ("m4-none", "m4-ckpt")] {
        let none = by_name(none);
        let with = by_name(with);
        assert!(
            with.wasted_hours < none.wasted_hours,
            "checkpointing must strictly reduce wasted hours: \
             {} has {:.2}, {} has {:.2}",
            none.name,
            none.wasted_hours,
            with.name,
            with.wasted_hours,
        );
        assert!(with.resumes > 0, "{} resumed nothing", with.name);
        assert_eq!(none.resumes, 0, "no-checkpoint runs cannot resume");
    }
    for r in &rows {
        assert!(
            r.cost_usd() <= base.budget_usd,
            "{} exceeded budget: {}",
            r.name,
            r.cost_usd()
        );
        assert!(r.wasted_hours >= 0.0 && r.goodput_hours >= 0.0);
    }

    // byte-identical reproduction: the same seed and matrix render to
    // the same JSON (the property `icecloud serve` keys its cache on)
    let again = icecloud::sweep::run_matrix(&base, &scenarios, 3);
    assert_eq!(
        icecloud::experiments::sweep::to_json(&rows).to_string_compact(),
        icecloud::experiments::sweep::to_json(&again).to_string_compact(),
    );
}

#[test]
fn risk_aware_policy_runs_and_favors_cheap_stable_providers() {
    let mut c = base_config();
    c.policy = PolicyMode::RiskAware;
    c.outage = None;
    c.duration_s = DAY;
    let result = Campaign::new(c).run();
    // azure (cheapest, deepest) must emerge as the favored provider
    // without any hardcoded weights
    let azure_hours = result.provider_ops[2].2;
    let aws_hours = result.provider_ops[0].2;
    assert!(
        azure_hours > aws_hours,
        "risk-aware must favor azure ({azure_hours:.1} vs {aws_hours:.1})"
    );
    assert!(result.schedd_stats.completed > 0);
}

/// PR 10 axis 1 (fractional-GPU accounting, arXiv:2205.09232): slot
/// carve-up is a pure accounting lens.  The same campaign replayed
/// with `gpu_slots_per_instance = 4` bills identical spend and
/// instance-hours, books exactly 1/4 the busy instance-hours, and the
/// DESIGN.md §15 conservation identity holds per provider with the
/// slot factor in place.
#[test]
fn gpu_slot_carveup_divides_busy_hours_end_to_end() {
    let mut whole_cfg = base_config();
    whole_cfg.duration_s = DAY;
    whole_cfg.outage = None;
    let mut carved_cfg = whole_cfg.clone();
    carved_cfg.gpu_slots_per_instance = 4;

    let whole = Campaign::new(whole_cfg).run();
    let carved = Campaign::new(carved_cfg).run();

    // billing is unchanged: the instance is billed whole however it
    // is carved
    assert!(
        (whole.meter.total_spend() - carved.meter.total_spend()).abs()
            < 1e-9,
        "spend must not depend on slot carve-up"
    );
    assert!(
        (whole.meter.total_instance_hours()
            - carved.meter.total_instance_hours())
        .abs()
            < 1e-9,
        "instance-hours must not depend on slot carve-up"
    );
    // busy occupancy is booked per slot: 4 slots -> 1/4 the
    // instance-equivalent busy hours, same replay
    assert!(whole.meter.total_busy_hours() > 0.0);
    assert!(
        (whole.meter.total_busy_hours()
            - 4.0 * carved.meter.total_busy_hours())
        .abs()
            < 1e-6,
        "whole={} carved={}",
        whole.meter.total_busy_hours(),
        carved.meter.total_busy_hours()
    );
    // conservation with the slot factor: goodput + badput + inflight
    // == busy_hours x slots x 3600, per provider
    for (i, p) in Provider::ALL.into_iter().enumerate() {
        let w = carved.provider_work[i];
        let busy_s = carved.meter.provider(p).busy_hours * 4.0 * 3600.0;
        let split = (w.goodput_s + w.badput_s + w.inflight_s) as f64;
        assert!(
            (busy_s - split).abs() < 1.0,
            "{p:?}: busy x slots {busy_s} != split {split}"
        );
    }
}

/// PR 10 axis 2 (checkpoint transfer cost, arXiv:2308.07999): a
/// checkpoint image that must cross the network before a resume adds
/// `ceil(size_gb x 8000 / mbps)` seconds to every resume's overhead —
/// 8 GB over 50 Mbit/s is 1280 s on top of the 120 s restore, and that
/// cost must show up as strictly more wasted hours and strictly less
/// goodput under churn.
#[test]
fn checkpoint_transfer_cost_shows_up_as_wasted_hours() {
    let mut base = base_config();
    base.duration_s = DAY;
    base.outage = Some(OutageSpec { at_s: 12 * HOUR, duration_s: HOUR });
    base.preempt_multiplier = 4.0;
    base.checkpoint = CheckpointPolicy::Interval {
        every_s: 1800,
        resume_overhead_s: 120,
    };

    let free = ScenarioConfig::named("transfer-free");
    let mut costly = ScenarioConfig::named("transfer-costly");
    costly.checkpoint_size_gb = Some(8.0);
    costly.checkpoint_transfer_mbps = Some(50.0);

    // the override reaches the effective policy through the single
    // registry-backed hook
    let applied = costly.apply(&base);
    assert_eq!(applied.checkpoint_transfer_s(), 1280);
    assert_eq!(
        applied.effective_checkpoint(),
        CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 120 + 1280,
        }
    );

    let rows =
        icecloud::sweep::run_matrix(&base, &[free, costly], 2);
    let by_name = |n: &str| {
        rows.iter().find(|r| r.name == n).expect("scenario row present")
    };
    let free = by_name("transfer-free");
    let costly = by_name("transfer-costly");
    assert!(free.resumes > 0, "churn must force resumes");
    assert!(costly.resumes > 0, "churn must force resumes");
    assert!(
        costly.wasted_hours > free.wasted_hours,
        "transfer cost must waste hours: costly={:.2} free={:.2}",
        costly.wasted_hours,
        free.wasted_hours
    );
    assert!(
        costly.goodput_hours < free.goodput_hours,
        "transfer cost must eat goodput: costly={:.2} free={:.2}",
        costly.goodput_hours,
        free.goodput_hours
    );
}

#[test]
fn badput_stays_bounded_with_tuned_keepalive() {
    let mut c = base_config();
    c.outage = None;
    let result = Campaign::new(c).run();
    let good = result.schedd_stats.goodput_s as f64;
    let bad = result.schedd_stats.badput_s as f64;
    // spot churn exists, but badput must stay a small fraction
    assert!(bad / (good + bad) < 0.1, "badput fraction {}", bad / (good + bad));
}
