//! The end-to-end driver: the paper's full two-week campaign with REAL
//! compute flowing through all three layers.
//!
//! Run with: `cargo run --release --example two_week_campaign`
//! (requires `python -m compile.aot` first)
//!
//! * L3 (this binary): the Rust coordinator replays the 14-day,
//!   2000-GPU-peak multi-cloud campaign — ramp plan, spot preemption,
//!   CloudBank budget control, the day-11 CE outage, resume at 1k.
//! * L2/L1: for every 200th completed IceCube job the coordinator
//!   executes the AOT-compiled JAX+Pallas photon-propagation artifact
//!   through PJRT and accumulates real physics output (DOM hits).
//!
//! Writes Fig 1 / Fig 2 / headline outputs into `results/e2e/` and prints
//! the paper-vs-measured table. Recorded in EXPERIMENTS.md §E2E.

use icecloud::config::{CampaignConfig, RealComputeConfig};
use icecloud::coordinator::Campaign;
use icecloud::experiments;
use icecloud::runtime::PhotonEngine;
use std::path::PathBuf;

fn main() {
    let artifact_dir = std::env::var("ICECLOUD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });

    let mut cfg = CampaignConfig::default();
    cfg.real_compute = Some(RealComputeConfig {
        variant: "default".into(),
        every_n_completions: 200,
    });

    println!("== two_week_campaign: full campaign + real PJRT compute ==\n");
    let engine = match PhotonEngine::new(&artifact_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `python -m compile.aot` (from python/) first");
            std::process::exit(1);
        }
    };
    println!("photon runtime: {}", engine.platform());
    let exe = engine.compile("default").expect("compile default variant");
    println!(
        "compiled photon artifact: {} photons x {} steps, {} DOMs, \
         {:.2e} FLOP/bunch\n",
        exe.meta.num_photons,
        exe.meta.num_steps,
        exe.meta.num_doms,
        exe.meta.flops_estimate
    );

    let t0 = std::time::Instant::now();
    let result = Campaign::with_engine(cfg, Some(exe)).run();
    println!(
        "\n14 simulated days replayed in {:.1?} wall clock\n",
        t0.elapsed()
    );

    // figures + headline from the same run
    let out = PathBuf::from("results/e2e");
    let fig1 = experiments::fig1::write(&result, &out).unwrap();
    println!("{}", fig1.chart());
    let fig2 = experiments::fig2::write(&result, &out).unwrap();
    println!("{}", fig2.chart());
    let headline = experiments::headline::write(&result, &out).unwrap();
    println!("{}", headline.table());
    headline.check_shape().expect("headline shape");

    // the real-compute evidence that all three layers composed
    let rc = result.real_compute;
    assert!(rc.bunches > 0, "real compute must have executed");
    println!(
        "real compute through PJRT: {} bunches, {:.1}M photons propagated, \
         {:.0} DOM detections, {:.1} s device wall, {:.2} Mphotons/s, \
         {:.2} GFLOP/s sustained",
        rc.bunches,
        rc.photons as f64 / 1e6,
        rc.detected,
        rc.wall_s,
        rc.photons_per_sec() / 1e6,
        rc.flops_per_sec() / 1e9,
    );
    println!("\noutputs in results/e2e/");
}
