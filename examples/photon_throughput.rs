//! Pure-runtime driver: photon artifact latency/throughput across variants.
//!
//! Run with: `cargo run --release --example photon_throughput`
//! (requires `python -m compile.aot`)
//!
//! Loads every AOT variant, executes a batch of bunches through the
//! native photon engine, and reports latency percentiles, photon
//! throughput and sustained FLOP rate — the serving-style view of the
//! L1/L2 stack that the campaign's real-compute sampling uses.
//! EXPERIMENTS.md §Perf uses these numbers for the L1 record.

use icecloud::runtime::PhotonEngine;
use icecloud::util::stats;
use std::path::PathBuf;

fn main() {
    let artifact_dir = std::env::var("ICECLOUD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    let engine = match PhotonEngine::new(&artifact_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `python -m compile.aot` (from python/) first");
            std::process::exit(1);
        }
    };
    println!("photon runtime: {}\n", engine.platform());
    println!(
        "{:<10} {:>10} {:>6} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "variant", "photons", "doms", "p50 ms", "p95 ms", "mean ms",
        "Mphotons/s", "GFLOP/s"
    );

    let bunches = 12usize;
    for name in ["small", "default", "large"] {
        let Ok(exe) = engine.compile(name) else {
            continue;
        };
        // warmup
        let _ = exe.run_seeded(0).unwrap();
        let mut lat = Vec::with_capacity(bunches);
        let mut detected = 0.0f64;
        for seed in 0..bunches {
            let r = exe.run_seeded(seed as u32 + 1).unwrap();
            lat.push(r.wall_s);
            detected += r.detected() as f64;
        }
        let ps = stats::percentiles(&lat, &[0.5, 0.95]);
        let mean = lat.iter().sum::<f64>() / lat.len() as f64;
        let photons_per_s = exe.meta.num_photons as f64 / mean;
        let gflops = exe.meta.flops_estimate / mean / 1e9;
        println!(
            "{:<10} {:>10} {:>6} {:>10.2} {:>10.2} {:>10.2} {:>12.3} {:>10.2}",
            name,
            exe.meta.num_photons,
            exe.meta.num_doms,
            ps[0] * 1e3,
            ps[1] * 1e3,
            mean * 1e3,
            photons_per_s / 1e6,
            gflops
        );
        assert!(detected > 0.0, "variant {name} must detect photons");
    }
    println!(
        "\nnote: native-engine CPU numbers (DESIGN.md §9); accelerator \
         throughput is modeled analytically via ACHIEVED_EFFICIENCY."
    );
}
