//! The §IV Azure NAT incident, reproduced as a keepalive ablation.
//!
//! Run with: `cargo run --release --example nat_timeout_ablation`
//!
//! Sweeps the HTCondor keepalive interval across Azure's 240 s NAT idle
//! timeout on an Azure-only fleet. With the OSG default (300 s) every
//! management connection silently dies between keepalives — "constant
//! preemption of the user jobs" — while any interval <= 240 s is stable.

use icecloud::experiments::nat;
use icecloud::sim::HOUR;

fn main() {
    println!("== NAT timeout ablation (Azure default NAT: 240 s idle) ==\n");
    println!(
        "sweeping keepalive ∈ {:?} s over a 12 h / 100-GPU Azure fleet\n",
        nat::DEFAULT_KEEPALIVES
    );
    let rows = nat::run_sweep(&nat::DEFAULT_KEEPALIVES, 12 * HOUR, 100);
    println!("{}", nat::render(&rows));
    match nat::check_cliff(&rows) {
        Ok(()) => println!("cliff check: OK — the paper's incident reproduces"),
        Err(e) => {
            eprintln!("cliff check FAILED: {e}");
            std::process::exit(1);
        }
    }
}
