use icecloud::config::CampaignConfig;
use icecloud::coordinator::Campaign;
fn main() {
    let t0 = std::time::Instant::now();
    let result = Campaign::new(CampaignConfig::default()).run();
    println!("wall: {:.2?} completed={}", t0.elapsed(), result.schedd_stats.completed);
}
