//! CloudBank budget management demo (§III of the paper).
//!
//! Run with: `cargo run --release --example budget_guardrails`
//!
//! Runs a deliberately under-funded campaign and shows the CloudBank
//! services in action: the single-window budget snapshot, the
//! threshold-crossing alert emails with spend rates, and the operator
//! guardrail that deprovisions the fleet when the reserve is reached.

use icecloud::cloudbank::report;
use icecloud::config::{CampaignConfig, RampStep};
use icecloud::coordinator::Campaign;
use icecloud::sim::DAY;

fn main() {
    let mut cfg = CampaignConfig::default();
    cfg.duration_s = 4 * DAY;
    cfg.outage = None;
    cfg.ramp = vec![RampStep { target: 300, hold_s: 60 * DAY }];
    cfg.onprem.slots = 0;
    cfg.generator.min_backlog = 600;
    // a budget that ~300 GPUs will burn through in about 3 days
    cfg.budget_usd = 2_800.0;
    cfg.alert_thresholds = vec![0.75, 0.5, 0.25, 0.1];

    println!("== budget guardrails: $2.8k budget, 300-GPU fleet, 4 days ==\n");
    let result = Campaign::new(cfg).run();

    // the "web page": single-window spend across all three providers
    println!("{}", report::render_snapshot(&result.ledger.snapshot(4 * DAY)));

    // the alert emails
    println!("alert emails ({}):", result.ledger.alerts().len());
    for a in result.ledger.alerts() {
        println!(
            "  [day {:.2}] threshold {:>4.0}% — {}",
            a.at as f64 / DAY as f64,
            a.threshold * 100.0,
            a.body
        );
    }

    // the guardrail: fleet must be drained before the money ran out
    let gpus = result.monitor.get("gpus.total").unwrap();
    let frac = result.ledger.remaining_fraction();
    println!(
        "\nfinal fleet size: {:.0} GPUs; remaining budget: {:.1}%",
        gpus.last().unwrap(),
        frac * 100.0
    );
    assert!(result.ledger.alerts().len() >= 3, "thresholds must fire");
    assert_eq!(gpus.last().unwrap(), 0.0, "guardrail must drain the fleet");
    assert!(frac > 0.0, "the budget must never go negative");
    println!("guardrail check: OK — fleet drained before exhausting funds");
}
