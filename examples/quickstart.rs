//! Quickstart: a one-day, 60-GPU multi-cloud campaign.
//!
//! Run with: `cargo run --release --example quickstart`
//!
//! Demonstrates the public API in ~30 lines: configure, run, inspect.

use icecloud::config::{CampaignConfig, RampStep};
use icecloud::coordinator::Campaign;
use icecloud::sim::{DAY, HOUR};

fn main() {
    // start from the paper's defaults, shrink to a quick demo
    let mut cfg = CampaignConfig::default();
    cfg.duration_s = DAY;
    cfg.ramp = vec![
        RampStep { target: 20, hold_s: 4 * HOUR }, // validation
        RampStep { target: 60, hold_s: 30 * DAY }, // scale up
    ];
    cfg.outage = None; // keep the quickstart calm
    cfg.onprem.slots = 40;
    cfg.generator.min_backlog = 200;

    println!("icecloud quickstart: 1 simulated day, 60 cloud GPUs + 40 on-prem\n");
    let result = Campaign::new(cfg).run();

    let h = icecloud::experiments::headline::extract(&result);
    println!("{}", h.table());

    let gpus = result.monitor.get("gpus.total").unwrap();
    println!(
        "cloud fleet: peak {:.0} GPUs, final {:.0}; {} jobs completed, \
         {:.1} cloud GPU-hours delivered for ${:.2}",
        gpus.max(),
        gpus.last().unwrap(),
        result.schedd_stats.completed,
        result.usage.total_cloud_gpu_hours(),
        result.ledger.total_spent(),
    );
}
